//! Flow-wide observability: a hierarchical span tracer plus a typed
//! metrics registry, near-zero-overhead when disabled.
//!
//! Every layer of the flow reports into this one substrate:
//!
//! * compile stages ([`crate::flow::CompileSession`] lower / analyze /
//!   synthesize / verify) open parent spans; each pass run by the
//!   [`crate::pass::PassManager`] and each analysis family run by
//!   [`crate::analysis::analyze`] opens a child span;
//! * host execution emits per-layer spans
//!   ([`crate::quant::exec::FastExecutor::forward_traced`],
//!   [`crate::quant::exec::Executor::forward_traced`], the verify
//!   interpreter's per-kernel dispatch) and scratch hit/miss counters;
//! * the DSE emits one span per candidate with synthesis-cache hit
//!   attribution;
//! * the serving coordinator emits a request-lifecycle span tree
//!   (`request` → `queued`/`execute`) plus batch and engine spans, and
//!   re-registers its [`crate::metrics::LatencyStats`] /
//!   [`crate::metrics::BatchHistogram`] snapshots as first-class metrics
//!   ([`crate::coordinator::StatsSnapshot::export_metrics`]).
//!
//! Two export formats (docs/OBSERVABILITY.md):
//!
//! * **Chrome trace-event JSON** ([`Trace::to_chrome_json`]) — open the
//!   file in Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`;
//! * **Prometheus text** ([`metrics::Registry::render_prometheus`]).
//!
//! ## Enable/disable contract
//!
//! The tracer is a process-global switch ([`enable`]/[`disable`]), off by
//! default. Disabled, every instrumentation site reduces to one relaxed
//! atomic load (most sites hoist even that out of their inner loops) and
//! performs **zero heap allocations** — `rust/tests/alloc_regression.rs`
//! pins this, and `benches/obs_overhead.rs` asserts the disabled-mode
//! cost is ≤ 1% of a FastExecutor frame. Span guards created while the
//! tracer was enabled still record at drop even if it is disabled in
//! between, so the span tree never loses an `end`.
//!
//! Parent/child nesting uses a thread-local span stack: a span opened
//! while another is live on the same thread becomes its child. Spans on
//! other threads (pool workers, replica workers) start new roots under
//! their own `tid`, which is exactly how Perfetto renders tracks.

pub mod metrics;

pub use metrics::{Counter, Gauge, Histogram, Registry};

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// A span argument value (rendered into the Chrome event's `args`).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    Num(f64),
    Str(String),
    Bool(bool),
}

impl From<f64> for ArgValue {
    fn from(v: f64) -> ArgValue {
        ArgValue::Num(v)
    }
}

impl From<u64> for ArgValue {
    fn from(v: u64) -> ArgValue {
        ArgValue::Num(v as f64)
    }
}

impl From<usize> for ArgValue {
    fn from(v: usize) -> ArgValue {
        ArgValue::Num(v as f64)
    }
}

impl From<bool> for ArgValue {
    fn from(v: bool) -> ArgValue {
        ArgValue::Bool(v)
    }
}

impl From<&str> for ArgValue {
    fn from(v: &str) -> ArgValue {
        ArgValue::Str(v.to_string())
    }
}

impl From<String> for ArgValue {
    fn from(v: String) -> ArgValue {
        ArgValue::Str(v)
    }
}

impl ArgValue {
    fn to_json(&self) -> Json {
        match self {
            ArgValue::Num(n) => Json::Num(*n),
            ArgValue::Str(s) => Json::Str(s.clone()),
            ArgValue::Bool(b) => Json::Bool(*b),
        }
    }
}

/// One completed span.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    /// Unique id within the trace (allocation order, not start order).
    pub id: u64,
    /// Enclosing span on the same thread at open time, if any.
    pub parent: Option<u64>,
    /// Category (Chrome `cat`): `compile`, `pass`, `analysis`, `exec`,
    /// `verify`, `dse`, `serve`, `engine`.
    pub cat: &'static str,
    pub name: String,
    /// Microseconds since the tracer's epoch ([`enable`] time).
    pub start_us: u64,
    pub dur_us: u64,
    /// Stable per-thread id (dense, allocation order).
    pub tid: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

impl SpanEvent {
    /// The value of a numeric arg, if present.
    pub fn num_arg(&self, key: &str) -> Option<f64> {
        self.args.iter().find(|(k, _)| *k == key).and_then(|(_, v)| match v {
            ArgValue::Num(n) => Some(*n),
            _ => None,
        })
    }

    /// The value of a bool arg, if present.
    pub fn bool_arg(&self, key: &str) -> Option<bool> {
        self.args.iter().find(|(k, _)| *k == key).and_then(|(_, v)| match v {
            ArgValue::Bool(b) => Some(*b),
            _ => None,
        })
    }
}

/// A finished trace: the drained span list plus tree/query helpers and
/// the Chrome trace-event exporter.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<SpanEvent>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Spans with no parent (per-thread roots).
    pub fn roots(&self) -> Vec<&SpanEvent> {
        self.events.iter().filter(|e| e.parent.is_none()).collect()
    }

    /// Direct children of span `id`, in event order.
    pub fn children(&self, id: u64) -> Vec<&SpanEvent> {
        self.events.iter().filter(|e| e.parent == Some(id)).collect()
    }

    /// All spans in a category.
    pub fn in_cat(&self, cat: &str) -> Vec<&SpanEvent> {
        self.events.iter().filter(|e| e.cat == cat).collect()
    }

    /// First span with this exact name.
    pub fn find(&self, name: &str) -> Option<&SpanEvent> {
        self.events.iter().find(|e| e.name == name)
    }

    /// Count of spans with this exact name.
    pub fn count(&self, name: &str) -> usize {
        self.events.iter().filter(|e| e.name == name).count()
    }

    /// Chrome trace-event JSON (the "JSON Array Format" with a
    /// `traceEvents` wrapper), loadable in Perfetto. Every span is a
    /// complete (`ph: "X"`) event; ids and parents ride in `args` so the
    /// span tree survives the format round-trip.
    pub fn to_chrome_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::with_capacity(self.events.len() + 1);
        // Process-name metadata event: Perfetto shows it as the track
        // group title.
        let mut meta = BTreeMap::new();
        meta.insert("name".into(), Json::Str("process_name".into()));
        meta.insert("ph".into(), Json::Str("M".into()));
        meta.insert("pid".into(), Json::Num(1.0));
        meta.insert("tid".into(), Json::Num(0.0));
        let mut margs = BTreeMap::new();
        margs.insert("name".into(), Json::Str("fpga-flow".into()));
        meta.insert("args".into(), Json::Obj(margs));
        events.push(Json::Obj(meta));
        for e in &self.events {
            let mut m = BTreeMap::new();
            m.insert("name".into(), Json::Str(e.name.clone()));
            m.insert("cat".into(), Json::Str(e.cat.into()));
            m.insert("ph".into(), Json::Str("X".into()));
            m.insert("ts".into(), Json::Num(e.start_us as f64));
            m.insert("dur".into(), Json::Num(e.dur_us as f64));
            m.insert("pid".into(), Json::Num(1.0));
            m.insert("tid".into(), Json::Num(e.tid as f64));
            let mut args = BTreeMap::new();
            args.insert("span_id".into(), Json::Num(e.id as f64));
            if let Some(p) = e.parent {
                args.insert("parent_id".into(), Json::Num(p as f64));
            }
            for (k, v) in &e.args {
                args.insert((*k).into(), v.to_json());
            }
            m.insert("args".into(), Json::Obj(args));
            events.push(Json::Obj(m));
        }
        let mut root = BTreeMap::new();
        root.insert("traceEvents".into(), Json::Arr(events));
        root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
        Json::Obj(root)
    }

    /// Per-category span counts and summed self time — the `profile`
    /// command's summary table and the report's `observability.trace`
    /// section.
    pub fn summary_json(&self) -> Json {
        let mut cats: BTreeMap<&str, (u64, u64)> = BTreeMap::new();
        for e in &self.events {
            let c = cats.entry(e.cat).or_insert((0, 0));
            c.0 += 1;
            c.1 += e.dur_us;
        }
        let mut obj = BTreeMap::new();
        obj.insert("spans".into(), Json::Num(self.events.len() as f64));
        let mut by_cat = BTreeMap::new();
        for (cat, (n, us)) in cats {
            let mut c = BTreeMap::new();
            c.insert("spans".into(), Json::Num(n as f64));
            c.insert("total_us".into(), Json::Num(us as f64));
            by_cat.insert(cat.to_string(), Json::Obj(c));
        }
        obj.insert("by_category".into(), Json::Obj(by_cat));
        Json::Obj(obj)
    }
}

struct TracerState {
    epoch: Instant,
    events: Vec<SpanEvent>,
    next_id: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn state() -> &'static Mutex<Option<TracerState>> {
    static STATE: OnceLock<Mutex<Option<TracerState>>> = OnceLock::new();
    STATE.get_or_init(|| Mutex::new(None))
}

thread_local! {
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Is the global tracer recording? One relaxed atomic load — the only
/// cost every instrumentation site pays when tracing is off.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Start recording. Resets the epoch and drops any spans left from a
/// previous session that was never drained.
pub fn enable() {
    let mut st = state().lock().unwrap();
    *st = Some(TracerState { epoch: Instant::now(), events: Vec::new(), next_id: 1 });
    drop(st);
    ENABLED.store(true, Ordering::SeqCst);
}

/// Stop recording (buffered spans survive until [`take`]).
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Stop recording and drain the buffered spans into a [`Trace`].
pub fn take() -> Trace {
    disable();
    let mut st = state().lock().unwrap();
    match st.take() {
        Some(s) => Trace { events: s.events },
        None => Trace::default(),
    }
}

fn this_tid() -> u64 {
    TID.with(|t| *t)
}

/// Open a span; it records when the returned guard drops. When the
/// tracer is disabled this is a no-op that borrows `name` without
/// allocating.
#[inline]
pub fn span(cat: &'static str, name: &str) -> Span {
    if !enabled() {
        return Span { inner: None };
    }
    let (id, epoch) = {
        let mut st = state().lock().unwrap();
        match st.as_mut() {
            Some(s) => {
                let id = s.next_id;
                s.next_id += 1;
                (id, s.epoch)
            }
            None => return Span { inner: None },
        }
    };
    let parent = SPAN_STACK.with(|s| {
        let mut s = s.borrow_mut();
        let parent = s.last().copied();
        s.push(id);
        parent
    });
    Span {
        inner: Some(ActiveSpan {
            id,
            parent,
            cat,
            name: name.to_string(),
            epoch,
            start: Instant::now(),
            args: Vec::new(),
        }),
    }
}

/// Record a span with explicit endpoints (post-hoc lifecycle spans, e.g.
/// a serve request's queued/execute phases reconstructed at completion).
/// Returns the span id so callers can parent further spans under it.
pub fn span_at(
    cat: &'static str,
    name: &str,
    parent: Option<u64>,
    start: Instant,
    end: Instant,
    args: Vec<(&'static str, ArgValue)>,
) -> Option<u64> {
    if !enabled() {
        return None;
    }
    let mut st = state().lock().unwrap();
    let s = st.as_mut()?;
    let id = s.next_id;
    s.next_id += 1;
    let start_us = start.saturating_duration_since(s.epoch).as_micros() as u64;
    let end_us = end.saturating_duration_since(s.epoch).as_micros() as u64;
    s.events.push(SpanEvent {
        id,
        parent,
        cat,
        name: name.to_string(),
        start_us,
        dur_us: end_us.saturating_sub(start_us),
        tid: this_tid(),
        args,
    });
    Some(id)
}

struct ActiveSpan {
    id: u64,
    parent: Option<u64>,
    cat: &'static str,
    name: String,
    epoch: Instant,
    start: Instant,
    args: Vec<(&'static str, ArgValue)>,
}

/// RAII span guard: opened by [`span`], records its event on drop.
pub struct Span {
    inner: Option<ActiveSpan>,
}

impl Span {
    /// Attach an argument (builder style; no-op when the tracer was
    /// disabled at open time).
    pub fn arg(mut self, key: &'static str, value: impl Into<ArgValue>) -> Span {
        self.set_arg(key, value);
        self
    }

    /// Attach an argument whose value is only known mid-span (e.g. a
    /// synthesis cache hit discovered after the lookup).
    pub fn set_arg(&mut self, key: &'static str, value: impl Into<ArgValue>) {
        if let Some(a) = self.inner.as_mut() {
            a.args.push((key, value.into()));
        }
    }

    /// The span's id while live (None when the tracer was disabled).
    pub fn id(&self) -> Option<u64> {
        self.inner.as_ref().map(|a| a.id)
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(a) = self.inner.take() else { return };
        let dur_us = a.start.elapsed().as_micros() as u64;
        let start_us = a.start.saturating_duration_since(a.epoch).as_micros() as u64;
        SPAN_STACK.with(|s| {
            let mut s = s.borrow_mut();
            if s.last() == Some(&a.id) {
                s.pop();
            } else {
                // Out-of-order drop (guard moved across scopes): remove
                // wherever it sits so the stack cannot grow unbounded.
                s.retain(|&id| id != a.id);
            }
        });
        let mut st = state().lock().unwrap();
        if let Some(s) = st.as_mut() {
            s.events.push(SpanEvent {
                id: a.id,
                parent: a.parent,
                cat: a.cat,
                name: a.name,
                start_us,
                dur_us,
                tid: this_tid(),
                args: a.args,
            });
        }
    }
}

/// The process-global metrics registry every instrumentation site
/// reports into (sites gate on [`enabled`], so a disabled run leaves it
/// empty).
pub fn global_metrics() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::new)
}

/// The report's `observability` section: the metrics snapshot plus a
/// trace summary ([`crate::flow::Accelerator::to_json_with_observability`]).
pub fn observability_json(trace: Option<&Trace>) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("metrics".into(), global_metrics().to_json());
    if let Some(t) = trace {
        obj.insert("trace".into(), t.summary_json());
    }
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, parent: Option<u64>, name: &str, cat: &'static str) -> SpanEvent {
        SpanEvent {
            id,
            parent,
            cat,
            name: name.into(),
            start_us: id * 10,
            dur_us: 5,
            tid: 1,
            args: vec![("n", ArgValue::Num(id as f64))],
        }
    }

    #[test]
    fn trace_tree_queries() {
        let t = Trace {
            events: vec![
                ev(1, None, "lower", "compile"),
                ev(2, Some(1), "pass.unroll", "pass"),
                ev(3, Some(1), "pass.fuse", "pass"),
                ev(4, None, "synthesize", "compile"),
            ],
        };
        assert_eq!(t.roots().len(), 2);
        assert_eq!(t.children(1).len(), 2);
        assert_eq!(t.in_cat("pass").len(), 2);
        assert_eq!(t.find("synthesize").unwrap().id, 4);
        assert_eq!(t.count("pass.unroll"), 1);
    }

    #[test]
    fn chrome_export_shape() {
        let t = Trace { events: vec![ev(1, None, "lower", "compile"), ev(2, Some(1), "p", "pass")] };
        let j = crate::util::json::parse(&t.to_chrome_json().to_string()).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        // Metadata event + 2 spans.
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("M"));
        let e = &events[1];
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("name").unwrap().as_str(), Some("lower"));
        assert_eq!(e.get("cat").unwrap().as_str(), Some("compile"));
        assert_eq!(e.get("ts").unwrap().as_u64(), Some(10));
        assert_eq!(e.get("dur").unwrap().as_u64(), Some(5));
        let args = &events[2].get("args").unwrap();
        assert_eq!(args.get("parent_id").unwrap().as_u64(), Some(1));
        assert_eq!(args.get("span_id").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn summary_groups_by_category() {
        let t = Trace {
            events: vec![ev(1, None, "a", "compile"), ev(2, None, "b", "pass"), ev(3, None, "c", "pass")],
        };
        let j = t.summary_json();
        assert_eq!(j.get("spans").unwrap().as_u64(), Some(3));
        let pass = j.get("by_category").unwrap().get("pass").unwrap();
        assert_eq!(pass.get("spans").unwrap().as_u64(), Some(2));
        assert_eq!(pass.get("total_us").unwrap().as_u64(), Some(10));
    }

    #[test]
    fn disabled_span_is_inert() {
        // The global tracer defaults to off; guards must be no-ops with
        // no id and no recorded event.
        assert!(!enabled());
        let mut s = span("compile", "nothing");
        assert_eq!(s.id(), None);
        s.set_arg("k", 1u64);
        drop(s);
        assert_eq!(span_at("compile", "n", None, Instant::now(), Instant::now(), vec![]), None);
    }

    #[test]
    fn span_event_arg_accessors() {
        let mut e = ev(1, None, "x", "exec");
        e.args.push(("hit", ArgValue::Bool(true)));
        assert_eq!(e.num_arg("n"), Some(1.0));
        assert_eq!(e.bool_arg("hit"), Some(true));
        assert_eq!(e.num_arg("missing"), None);
    }
}
