//! Structural and resource diagnostics (FLOW030–FLOW037).
//!
//! These unify what used to be three disjoint checkers: the verify
//! interpreter's structural pass (autorun legality, lost nodes, epilogue
//! divergence, stash sizing — `verify/interp.rs` now delegates here), the
//! §IV-J rule-3 pre-check over the [`aoc::resources`](crate::aoc::resources)
//! model, and the folded stash-capacity rule that was independently
//! re-derived by the scheduler. One implementation, one [`Diagnostic`]
//! vocabulary.

use crate::analysis::{Diagnostic, Lint, Span, View};
use crate::codegen::{Kernel, KernelProgram};
use crate::device::FpgaDevice;
use crate::graph::{Graph, Op};
use crate::texpr::{Epilogue, LoopVar, MemSpace};
use crate::verify::interp::expected_intrinsic;

/// Utilization fraction above which routing failure becomes likely (the
/// congestion model's feasible region ends well before 100%).
pub const NEAR_BUDGET_FRAC: f64 = 0.85;

pub(crate) fn check(view: &View) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let prog = view.program;
    let g = view.graph;

    // FLOW033/FLOW034: autorun legality (§IV-F) — no global arguments, no
    // weights.
    for k in &prog.kernels {
        if k.autorun {
            if !k.autorun_eligible() {
                out.push(Diagnostic::new(
                    Lint::AutorunGlobal,
                    Span::kernel(k.name.clone()),
                    format!("kernel {} is autorun but accesses global memory", k.name),
                ));
            }
            if g.nodes[k.layers[0]].op.has_weights() {
                out.push(Diagnostic::new(
                    Lint::AutorunWeights,
                    Span::kernel(k.name.clone()),
                    format!("kernel {} is autorun but its op carries weights", k.name),
                ));
            }
        }
    }

    // FLOW035: every non-layout graph node must survive lowering — either
    // it owns a kernel or it is an absorbed epilogue of one.
    let mut covered: std::collections::BTreeSet<usize> = view.map.keys().copied().collect();
    for chain in view.chains.values() {
        covered.extend(chain.iter().copied());
    }
    for n in g.topo() {
        if matches!(n.op, Op::Input | Op::Flatten | Op::Transform) {
            continue;
        }
        if !covered.contains(&n.id) {
            out.push(Diagnostic::new(
                Lint::NodeLost,
                Span::node(n.name.clone()),
                format!("node {} ({}) was lost by lowering", n.name, n.op.mnemonic()),
            ));
        }
    }

    // FLOW036/FLOW037: the recorded epilogue/absorbed chain of each kernel
    // must match the graph for its representative layer. (Member layers of
    // a parameterized group resolve their chains at dispatch.)
    for k in &prog.kernels {
        let rep = k.layers[0];
        let chain = &view.chains[&rep];
        if &k.absorbed != chain {
            out.push(Diagnostic::new(
                Lint::AbsorbedMismatch,
                Span::kernel(k.name.clone()),
                format!(
                    "kernel {} records absorbed nodes {:?} but the graph chain is {chain:?}",
                    k.name, k.absorbed
                ),
            ));
        }
        let mut expected = expected_intrinsic(&g.nodes[rep].op);
        for &a in chain {
            expected.push(match g.nodes[a].op {
                Op::BatchNorm => Epilogue::BatchNormFold,
                Op::Activate(act) => Epilogue::Activation(act),
                _ => continue,
            });
        }
        if k.nest.epilogue != expected {
            out.push(Diagnostic::new(
                Lint::EpilogueDivergence,
                Span::kernel(k.name.clone()),
                format!(
                    "kernel {} epilogue {:?} diverges from the graph-implied {:?}",
                    k.name, k.nest.epilogue, expected
                ),
            ));
        }
    }

    // FLOW032: folded stash capacity.
    for k in &prog.kernels {
        out.extend(stash_capacity(g, k));
    }

    out
}

/// §IV-H stash rule, the single implementation both the analyzer and the
/// verify interpreter consult: a folded ifmap stash must hold at least the
/// strip it stages — double-buffered, `kernel` input rows at the widest
/// member layer's actual row width, times the achieved input-channel tile
/// (the nest's InC unroll — never larger than the plan tile the stash was
/// sized for). Over-sizing is a cost bug only; under-sizing (e.g. a
/// hard-coded on-chip width) deadlocks the strip loader and is flagged.
pub fn stash_capacity(graph: &Graph, k: &Kernel) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let node = &graph.nodes[k.layers[0]];
    let Some(grp) = node.op.param_group() else {
        return out;
    };
    let eb = k.nest.precision.bytes();
    let t_inner = k.nest.find_loop(LoopVar::InC).map(|l| l.unroll.max(1)).unwrap_or(1);
    for a in &k.nest.accesses {
        if a.space == MemSpace::Local && a.buffer == "ifmap" {
            let max_w = crate::pass::schedule::max_input_width(graph, &k.layers);
            let need = 2 * t_inner * grp.kernel as u64 * max_w * eb;
            if a.array_bytes < need {
                out.push(Diagnostic::new(
                    Lint::StashCapacity,
                    Span::kernel(k.name.clone()),
                    format!(
                        "kernel {}: ifmap stash of {} B cannot hold its {} B double-buffered \
                         line strip",
                        k.name, a.array_bytes, need
                    ),
                ));
            }
        }
    }
    out
}

/// FLOW030/FLOW031: §IV-J rule-3 pre-check. Synthesis re-derives the same
/// model ([`crate::aoc::resources::program_resources`]) before routing;
/// flagging it here turns an hour-long Quartus failure into a static lint.
pub(crate) fn check_budget(prog: &KernelProgram, dev: &FpgaDevice) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let res = crate::aoc::resources::program_resources(prog, dev);
    let util = &res.utilization;
    for (dim, frac) in crate::aoc::resources::over_budget(util) {
        out.push(Diagnostic::new(
            Lint::OverBudget,
            Span::default(),
            format!(
                "modeled {dim} utilization {:.0}% exceeds the device budget by {:.0}% \
                 (§IV-J rule 3)",
                frac * 100.0,
                (frac - 1.0) * 100.0
            ),
        ));
    }
    if util.fits() && util.max_frac() > NEAR_BUDGET_FRAC {
        let (dim, frac) = util.peak();
        out.push(Diagnostic::new(
            Lint::NearBudget,
            Span::default(),
            format!(
                "modeled {dim} utilization {:.0}% is above the {:.0}% routing-risk threshold",
                frac * 100.0,
                NEAR_BUDGET_FRAC * 100.0
            ),
        ));
    }
    out
}
