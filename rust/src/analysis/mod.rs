//! Static design-rule analysis: deadlock, overflow and resource
//! diagnostics *before* synthesis.
//!
//! The paper's optimizations each carry legality obligations —
//! channelization must not deadlock (§IV-E/§IV-J), int8 datapaths must not
//! wrap their accumulators (§VII), folded stashes must hold their strips
//! (§IV-H) — that were historically checked in three inconsistent places:
//! `flow::legality` strings, the `verify` interpreter's structural pass,
//! and scattered panics. Most failures then surfaced *dynamically*, when
//! the differential harness happened to execute a bad program. This module
//! rejects illegal designs statically and explains why, the way a compiler
//! front-end reports lints: every finding is a [`Diagnostic`] with a
//! stable lint code (`FLOW0xx`), a [`Severity`], and a structured [`Span`]
//! naming the offending kernel/channel/node.
//!
//! The analyzer runs as the `analyze` stage of the staged compile API,
//! between lowering and synthesis
//! ([`CompileSession::analyze`](crate::flow::CompileSession::analyze)),
//! and behind `fpga-flow check`. Analyses:
//!
//! * [`deadlock`] — cycle detection over the channel topology plus a
//!   per-frame token-count analysis proving every channel's writes and
//!   reads balance under the recorded dispatch order and channel depths;
//! * [`overflow`] — abstract value-range propagation through the
//!   int8/fp16 datapath from calibrated ranges and layer reduction
//!   extents, proving the integer accumulators cannot wrap;
//! * [`structure`] — resource-budget, stash-capacity and structural
//!   well-formedness diagnostics (autorun legality, lost nodes, epilogue
//!   divergence), shared with the `verify` interpreter;
//! * [`consistency`] — per-pass lints cross-checking each pass's declared
//!   [`Equivalence`](crate::pass::Equivalence) obligation against its
//!   trace record.
//!
//! §IV-J rules 1/2 ([`crate::flow::legality::check_program`]) emit the
//! same [`Diagnostic`] type, so `fpga-flow check` and `report_json`
//! surface every design-rule family uniformly.

pub mod consistency;
pub mod deadlock;
pub mod overflow;
pub mod structure;

use std::collections::BTreeMap;

use crate::codegen::KernelProgram;
use crate::device::FpgaDevice;
use crate::graph::{Graph, NodeId};
use crate::pass::PassTrace;
use crate::util::json::Json;

/// Lint severity, ordered `Note < Warning < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Informational — never fails a check.
    Note,
    /// Suspicious but not provably wrong; fails under `--deny warnings`.
    Warning,
    /// Provably violates a design rule; the design must not synthesize.
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Note => "note",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// The stable lint registry. Codes are append-only: a code is never
/// renumbered or reused, so downstream tooling can match on them
/// (`docs/ANALYSIS.md` is the human catalog).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lint {
    /// FLOW001: the channel topology contains a cycle — no kernel in it
    /// can ever fire.
    ChannelCycle,
    /// FLOW002: a channel's per-frame writes and reads do not balance.
    ChannelTokenImbalance,
    /// FLOW003: a channel's depth cannot buffer its producer's feature map
    /// under the sequential dispatch order (§IV-J).
    ChannelUnderDepth,
    /// FLOW004: a channel endpoint names no kernel.
    ChannelDangling,
    /// FLOW005: a channel's element type differs from its producer's
    /// datapath precision.
    ChannelElemMismatch,
    /// FLOW006: a cross-kernel graph edge has no channel.
    ChannelMissing,
    /// FLOW007: a channel matches no graph edge (it can never drain).
    ChannelOrphan,
    /// FLOW008: a kernel's outputs are never consumed.
    DeadKernel,
    /// FLOW010: an int8 accumulator can wrap its 32-bit C type.
    AccumOverflow,
    /// FLOW011: an int8 accumulator is within 2× of wrapping.
    AccumMargin,
    /// FLOW012: a calibrated fp16 stream value exceeds the fp16 range.
    F16RangeOverflow,
    /// FLOW020: §IV-J rule 1 — a streamed operand exceeds the bandwidth
    /// roof.
    BandwidthRoof,
    /// FLOW021: §IV-J rule 2 — a loop extent is not divisible by its
    /// factor.
    NotDivisible,
    /// FLOW022: §VII #2 — a weight density outside the (0, 1] domain.
    SparsityDomain,
    /// FLOW030: modeled utilization exceeds the device (rule 3 pre-check).
    OverBudget,
    /// FLOW031: modeled utilization is close enough to the device ceiling
    /// to risk routing failure.
    NearBudget,
    /// FLOW032: a folded ifmap stash cannot hold its line strip.
    StashCapacity,
    /// FLOW033: an autorun kernel accesses global memory (§IV-F).
    AutorunGlobal,
    /// FLOW034: an autorun kernel's op carries weights (§IV-F).
    AutorunWeights,
    /// FLOW035: a graph node was lost by lowering.
    NodeLost,
    /// FLOW036: a kernel's epilogue diverges from the graph-implied chain.
    EpilogueDivergence,
    /// FLOW037: a kernel's absorbed-node record diverges from the graph.
    AbsorbedMismatch,
    /// FLOW053: a pipeline stage's modeled utilization exceeds its
    /// device's budget — the partition is not deployable as cut.
    PipelineStageOverBudget,
    /// FLOW054: consecutive pipeline stages disagree about the boundary
    /// tensor (host channel element count mismatch).
    PipelineBoundaryMismatch,
    /// FLOW055: the pipeline's bottleneck stage is transfer-bound — the
    /// host link, not any device, caps throughput, so adding devices
    /// cannot help until the cut moves.
    PipelineTransferBound,
    /// FLOW050: a pass recorded as skipped reports IR changes.
    TraceInconsistent,
    /// FLOW051: a pass's diff moved values onto a quantization grid but its
    /// declared equivalence obligation does not admit that.
    EquivalenceUnderstated,
    /// FLOW052: an applied pass matched sites but changed nothing.
    PassNoEffect,
}

impl Lint {
    /// Stable code (`FLOWnnn`).
    pub fn code(&self) -> &'static str {
        match self {
            Lint::ChannelCycle => "FLOW001",
            Lint::ChannelTokenImbalance => "FLOW002",
            Lint::ChannelUnderDepth => "FLOW003",
            Lint::ChannelDangling => "FLOW004",
            Lint::ChannelElemMismatch => "FLOW005",
            Lint::ChannelMissing => "FLOW006",
            Lint::ChannelOrphan => "FLOW007",
            Lint::DeadKernel => "FLOW008",
            Lint::AccumOverflow => "FLOW010",
            Lint::AccumMargin => "FLOW011",
            Lint::F16RangeOverflow => "FLOW012",
            Lint::BandwidthRoof => "FLOW020",
            Lint::NotDivisible => "FLOW021",
            Lint::SparsityDomain => "FLOW022",
            Lint::OverBudget => "FLOW030",
            Lint::NearBudget => "FLOW031",
            Lint::StashCapacity => "FLOW032",
            Lint::AutorunGlobal => "FLOW033",
            Lint::AutorunWeights => "FLOW034",
            Lint::NodeLost => "FLOW035",
            Lint::EpilogueDivergence => "FLOW036",
            Lint::AbsorbedMismatch => "FLOW037",
            Lint::PipelineStageOverBudget => "FLOW053",
            Lint::PipelineBoundaryMismatch => "FLOW054",
            Lint::PipelineTransferBound => "FLOW055",
            Lint::TraceInconsistent => "FLOW050",
            Lint::EquivalenceUnderstated => "FLOW051",
            Lint::PassNoEffect => "FLOW052",
        }
    }

    /// Short kebab-case slug (catalog key in `docs/ANALYSIS.md`).
    pub fn slug(&self) -> &'static str {
        match self {
            Lint::ChannelCycle => "channel-cycle",
            Lint::ChannelTokenImbalance => "channel-token-imbalance",
            Lint::ChannelUnderDepth => "channel-under-depth",
            Lint::ChannelDangling => "channel-dangling",
            Lint::ChannelElemMismatch => "channel-elem-mismatch",
            Lint::ChannelMissing => "channel-missing",
            Lint::ChannelOrphan => "channel-orphan",
            Lint::DeadKernel => "dead-kernel",
            Lint::AccumOverflow => "accum-overflow",
            Lint::AccumMargin => "accum-margin",
            Lint::F16RangeOverflow => "f16-range-overflow",
            Lint::BandwidthRoof => "bandwidth-roof",
            Lint::NotDivisible => "not-divisible",
            Lint::SparsityDomain => "sparsity-domain",
            Lint::OverBudget => "over-budget",
            Lint::NearBudget => "near-budget",
            Lint::StashCapacity => "stash-capacity",
            Lint::AutorunGlobal => "autorun-global",
            Lint::AutorunWeights => "autorun-weights",
            Lint::NodeLost => "node-lost",
            Lint::EpilogueDivergence => "epilogue-divergence",
            Lint::AbsorbedMismatch => "absorbed-mismatch",
            Lint::PipelineStageOverBudget => "pipeline-stage-over-budget",
            Lint::PipelineBoundaryMismatch => "pipeline-boundary-mismatch",
            Lint::PipelineTransferBound => "pipeline-transfer-bound",
            Lint::TraceInconsistent => "trace-inconsistent",
            Lint::EquivalenceUnderstated => "equivalence-understated",
            Lint::PassNoEffect => "pass-no-effect",
        }
    }

    pub fn severity(&self) -> Severity {
        match self {
            Lint::DeadKernel
            | Lint::AccumMargin
            | Lint::NearBudget
            | Lint::PipelineTransferBound
            | Lint::EquivalenceUnderstated => Severity::Warning,
            Lint::PassNoEffect => Severity::Note,
            _ => Severity::Error,
        }
    }

    /// Every registered lint, in code order (drives the catalog test).
    pub fn all() -> &'static [Lint] {
        &[
            Lint::ChannelCycle,
            Lint::ChannelTokenImbalance,
            Lint::ChannelUnderDepth,
            Lint::ChannelDangling,
            Lint::ChannelElemMismatch,
            Lint::ChannelMissing,
            Lint::ChannelOrphan,
            Lint::DeadKernel,
            Lint::AccumOverflow,
            Lint::AccumMargin,
            Lint::F16RangeOverflow,
            Lint::BandwidthRoof,
            Lint::NotDivisible,
            Lint::SparsityDomain,
            Lint::OverBudget,
            Lint::NearBudget,
            Lint::StashCapacity,
            Lint::AutorunGlobal,
            Lint::AutorunWeights,
            Lint::NodeLost,
            Lint::EpilogueDivergence,
            Lint::AbsorbedMismatch,
            Lint::TraceInconsistent,
            Lint::EquivalenceUnderstated,
            Lint::PassNoEffect,
            Lint::PipelineStageOverBudget,
            Lint::PipelineBoundaryMismatch,
            Lint::PipelineTransferBound,
        ]
    }
}

/// Structured location of a finding: which kernel/channel/node/pass the
/// lint is about. All fields optional — a program-wide finding carries an
/// empty span.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Span {
    pub kernel: Option<String>,
    pub channel: Option<String>,
    pub node: Option<String>,
    pub pass: Option<String>,
    /// Pipeline stage index, for multi-device partition findings.
    pub stage: Option<usize>,
}

impl Span {
    pub fn kernel(name: impl Into<String>) -> Span {
        Span { kernel: Some(name.into()), ..Span::default() }
    }

    pub fn stage(index: usize) -> Span {
        Span { stage: Some(index), ..Span::default() }
    }

    pub fn with_stage(mut self, index: usize) -> Span {
        self.stage = Some(index);
        self
    }

    pub fn channel(name: impl Into<String>) -> Span {
        Span { channel: Some(name.into()), ..Span::default() }
    }

    pub fn node(name: impl Into<String>) -> Span {
        Span { node: Some(name.into()), ..Span::default() }
    }

    pub fn pass(name: impl Into<String>) -> Span {
        Span { pass: Some(name.into()), ..Span::default() }
    }

    pub fn with_node(mut self, name: impl Into<String>) -> Span {
        self.node = Some(name.into());
        self
    }

    pub fn with_kernel(mut self, name: impl Into<String>) -> Span {
        self.kernel = Some(name.into());
        self
    }
}

/// One analyzer finding: a registered lint at a structured location with a
/// human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub lint: Lint,
    pub span: Span,
    pub message: String,
}

impl Diagnostic {
    pub fn new(lint: Lint, span: Span, message: impl Into<String>) -> Diagnostic {
        Diagnostic { lint, span, message: message.into() }
    }

    pub fn code(&self) -> &'static str {
        self.lint.code()
    }

    pub fn severity(&self) -> Severity {
        self.lint.severity()
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}[{}] {}", self.severity().name(), self.code(), self.message)
    }
}

/// The analyzer's report: every finding, in analysis order (channels →
/// overflow → legality → structure/budget → pass consistency).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AnalysisReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl AnalysisReport {
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity() == Severity::Error)
    }

    pub fn count(&self, sev: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity() == sev).count()
    }

    /// No errors; with `deny_warnings`, no warnings either. Notes never
    /// fail a check.
    pub fn is_clean(&self, deny_warnings: bool) -> bool {
        self.count(Severity::Error) == 0
            && (!deny_warnings || self.count(Severity::Warning) == 0)
    }

    /// One `severity[CODE] message` line per finding, plus a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.to_string());
            out.push('\n');
        }
        out.push_str(&format!(
            "{} error(s), {} warning(s), {} note(s)\n",
            self.count(Severity::Error),
            self.count(Severity::Warning),
            self.count(Severity::Note)
        ));
        out
    }

    /// Machine-readable report (the `diagnostics` section of
    /// `report_json` and `fpga-flow check --json`).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("errors".into(), Json::Num(self.count(Severity::Error) as f64));
        root.insert("warnings".into(), Json::Num(self.count(Severity::Warning) as f64));
        root.insert("notes".into(), Json::Num(self.count(Severity::Note) as f64));
        root.insert(
            "items".into(),
            Json::Arr(
                self.diagnostics
                    .iter()
                    .map(|d| {
                        let mut m = BTreeMap::new();
                        m.insert("code".into(), Json::Str(d.code().into()));
                        m.insert("lint".into(), Json::Str(d.lint.slug().into()));
                        m.insert("severity".into(), Json::Str(d.severity().name().into()));
                        m.insert("message".into(), Json::Str(d.message.clone()));
                        if let Some(k) = &d.span.kernel {
                            m.insert("kernel".into(), Json::Str(k.clone()));
                        }
                        if let Some(c) = &d.span.channel {
                            m.insert("channel".into(), Json::Str(c.clone()));
                        }
                        if let Some(n) = &d.span.node {
                            m.insert("node".into(), Json::Str(n.clone()));
                        }
                        if let Some(p) = &d.span.pass {
                            m.insert("pass".into(), Json::Str(p.clone()));
                        }
                        if let Some(s) = d.span.stage {
                            m.insert("stage".into(), Json::Num(s as f64));
                        }
                        Json::Obj(m)
                    })
                    .collect(),
            ),
        );
        Json::Obj(root)
    }
}

/// Shared program view: the node→kernel map, absorbed chains and consumer
/// lists every analysis consults. Built once per [`analyze`] call.
pub(crate) struct View<'a> {
    pub graph: &'a Graph,
    pub program: &'a KernelProgram,
    pub map: BTreeMap<NodeId, usize>,
    pub chains: BTreeMap<NodeId, Vec<NodeId>>,
    pub consumers: Vec<Vec<NodeId>>,
}

impl<'a> View<'a> {
    pub fn new(graph: &'a Graph, program: &'a KernelProgram) -> View<'a> {
        let map = crate::pass::schedule::node_kernel_map(program);
        let consumers = graph.consumers();
        let mut chains = BTreeMap::new();
        for &nid in map.keys() {
            chains.insert(
                nid,
                crate::verify::interp::absorbed_chain(graph, &map, &consumers, nid),
            );
        }
        View { graph, program, map, chains, consumers }
    }

    /// The kernel producing node `id`'s value, climbing through nodes that
    /// own no kernel (layout skips, fused epilogues) via their first input.
    pub fn producing_kernel(&self, mut id: NodeId) -> Option<usize> {
        loop {
            if let Some(&k) = self.map.get(&id) {
                return Some(k);
            }
            match self.graph.nodes[id].inputs.first() {
                Some(&prev) => id = prev,
                None => return None,
            }
        }
    }

    /// The last node of `host`'s absorbed chain (= the value the kernel's
    /// output stream actually carries), or `host` itself.
    pub fn output_node(&self, host: NodeId) -> NodeId {
        self.chains.get(&host).and_then(|c| c.last().copied()).unwrap_or(host)
    }
}

/// Structural findings for the verify interpreter, which keeps its legacy
/// message-string surface ([`Interpreter::structure`]) but no longer owns
/// an implementation. Cycle lints are excluded — the interpreter's
/// dispatch builder detects cycles itself (it also needs the fallback
/// dispatch order) and reports them on its own.
///
/// [`Interpreter::structure`]: crate::verify::interp::Interpreter::structure
pub(crate) fn structural_violations(graph: &Graph, program: &KernelProgram) -> Vec<Diagnostic> {
    let view = View::new(graph, program);
    let mut v = deadlock::check(&view);
    v.retain(|d| d.lint != Lint::ChannelCycle);
    v.extend(structure::check(&view));
    v
}

/// Run every analysis on a lowered program. `legality_clock_mhz` keys the
/// §IV-J rule-1 roof (the target's legality clock); `trace`, when present,
/// enables the per-pass consistency lints.
pub fn analyze(
    graph: &Graph,
    program: &KernelProgram,
    device: &FpgaDevice,
    legality_clock_mhz: f64,
    trace: Option<&PassTrace>,
) -> AnalysisReport {
    let view = View::new(graph, program);
    let mut diagnostics = Vec::new();
    // Each rule family gets a child span (under the session's `analyze`
    // stage span) whose `findings` arg counts what that family alone
    // contributed.
    let mut family = |name: &str, found: &mut dyn FnMut() -> Vec<Diagnostic>| {
        let mut span = crate::obs::span("analysis", name);
        let v = found();
        span.set_arg("findings", v.len());
        v
    };
    diagnostics.extend(family("deadlock", &mut || deadlock::check(&view)));
    diagnostics.extend(family("overflow", &mut || overflow::check(&view)));
    diagnostics.extend(family("legality", &mut || {
        crate::flow::legality::check_program(program, device, legality_clock_mhz)
    }));
    diagnostics.extend(family("structure", &mut || structure::check(&view)));
    diagnostics.extend(family("budget", &mut || structure::check_budget(program, device)));
    if let Some(trace) = trace {
        diagnostics.extend(family("consistency", &mut || consistency::check(trace)));
    }
    let report = AnalysisReport { diagnostics };
    if crate::obs::enabled() {
        let m = crate::obs::global_metrics();
        m.counter("flow_analyses_total", "analyzer runs").inc();
        m.counter("flow_diagnostics_error_total", "error diagnostics emitted")
            .add(report.count(Severity::Error) as u64);
        m.counter("flow_diagnostics_warning_total", "warning diagnostics emitted")
            .add(report.count(Severity::Warning) as u64);
        m.counter("flow_diagnostics_note_total", "note diagnostics emitted")
            .add(report.count(Severity::Note) as u64);
    }
    report
}

/// Per-stage facts the pipeline analyzer consumes — a plain projection of
/// [`crate::flow::multi::PipelinePlan`] so the analyzer stays decoupled
/// from the flow types that produce it.
#[derive(Debug, Clone)]
pub struct PipelineStageFacts {
    /// Stage network name (`"{parent}.s{i}"`).
    pub name: String,
    /// Device the stage was synthesized for.
    pub device: String,
    /// Modeled utilization of the stage's design on its device.
    pub utilization: crate::device::Utilization,
    /// Elements the stage's output tensor carries into the next host
    /// channel.
    pub out_elems: u64,
    /// Elements the stage's `Input` node expects from the previous stage.
    pub in_elems: u64,
    /// True when the stage's host-link transfer exceeds its compute.
    pub transfer_bound: bool,
    /// Pipeline interval the stage occupies (`max(compute, transfer)`).
    pub stage_s: f64,
}

/// Pipeline-partition analyses (FLOW053–FLOW055): per-stage resource
/// budgets, inter-stage host-channel element consistency, and
/// transfer-bound bottleneck attribution.
///
/// FLOW055 fires only for the *bottleneck* stage: a fast non-bottleneck
/// stage whose tiny compute is nominally below its transfer time costs
/// nothing (the transfer overlaps someone else's compute), but a
/// transfer-bound bottleneck means the host link — not any device — caps
/// throughput, so adding devices cannot help until the cut moves.
pub fn analyze_pipeline(stages: &[PipelineStageFacts]) -> AnalysisReport {
    let mut span = crate::obs::span("analysis", "pipeline");
    span.set_arg("stages", stages.len());
    let mut diagnostics = Vec::new();
    for (i, s) in stages.iter().enumerate() {
        for (dim, frac) in crate::aoc::resources::over_budget(&s.utilization) {
            diagnostics.push(Diagnostic::new(
                Lint::PipelineStageOverBudget,
                Span::stage(i).with_node(s.name.clone()),
                format!(
                    "stage {i} ({}) modeled {dim} utilization {:.0}% exceeds the {} budget \
                     by {:.0}% — move a cut or add a device",
                    s.name,
                    frac * 100.0,
                    s.device,
                    (frac - 1.0) * 100.0
                ),
            ));
        }
    }
    for i in 1..stages.len() {
        let (prev, cur) = (&stages[i - 1], &stages[i]);
        if prev.out_elems != cur.in_elems {
            diagnostics.push(Diagnostic::new(
                Lint::PipelineBoundaryMismatch,
                Span::stage(i).with_node(cur.name.clone()),
                format!(
                    "host channel between stage {} and stage {i} disagrees on the boundary \
                     tensor: {} produces {} elements but {} expects {}",
                    i - 1,
                    prev.name,
                    prev.out_elems,
                    cur.name,
                    cur.in_elems
                ),
            ));
        }
    }
    if let Some((i, s)) = stages
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.stage_s.total_cmp(&b.1.stage_s))
    {
        if s.transfer_bound {
            diagnostics.push(Diagnostic::new(
                Lint::PipelineTransferBound,
                Span::stage(i).with_node(s.name.clone()),
                format!(
                    "pipeline bottleneck stage {i} ({}) is transfer-bound: the host link, \
                     not the device, caps throughput at {:.1} ms/frame",
                    s.name,
                    s.stage_s * 1e3
                ),
            ));
        }
    }
    span.set_arg("findings", diagnostics.len());
    AnalysisReport { diagnostics }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_codes_are_unique_and_stable() {
        let mut seen = std::collections::BTreeSet::new();
        for l in Lint::all() {
            assert!(seen.insert(l.code()), "duplicate lint code {}", l.code());
            assert!(l.code().starts_with("FLOW"), "{}", l.code());
            assert!(!l.slug().is_empty());
        }
        // Stability spot checks — these codes are documented and must
        // never be renumbered.
        assert_eq!(Lint::ChannelCycle.code(), "FLOW001");
        assert_eq!(Lint::AccumOverflow.code(), "FLOW010");
        assert_eq!(Lint::BandwidthRoof.code(), "FLOW020");
        assert_eq!(Lint::StashCapacity.code(), "FLOW032");
        assert_eq!(Lint::TraceInconsistent.code(), "FLOW050");
    }

    #[test]
    fn severity_ordering_and_cleanliness() {
        assert!(Severity::Note < Severity::Warning);
        assert!(Severity::Warning < Severity::Error);
        let mut rep = AnalysisReport::default();
        assert!(rep.is_clean(true));
        rep.diagnostics.push(Diagnostic::new(Lint::NearBudget, Span::default(), "w"));
        assert!(rep.is_clean(false));
        assert!(!rep.is_clean(true));
        rep.diagnostics.push(Diagnostic::new(Lint::OverBudget, Span::default(), "e"));
        assert!(!rep.is_clean(false));
    }

    #[test]
    fn diagnostics_render_with_codes() {
        let d = Diagnostic::new(
            Lint::ChannelUnderDepth,
            Span::channel("ch0").with_kernel("conv1"),
            "channel ch0 depth 4 cannot buffer conv1's 100-element feature map (§IV-J)",
        );
        let line = d.to_string();
        assert!(line.starts_with("error[FLOW003]"), "{line}");
        assert!(line.contains("ch0"), "{line}");
    }

    #[test]
    fn report_json_carries_spans() {
        let rep = AnalysisReport {
            diagnostics: vec![Diagnostic::new(
                Lint::AccumOverflow,
                Span::kernel("fc").with_node("fc1"),
                "overflow",
            )],
        };
        let parsed = crate::util::json::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(parsed.get("errors").unwrap().as_u64(), Some(1));
        let item = parsed.get("items").unwrap().idx(0).unwrap();
        assert_eq!(item.get("code").unwrap().as_str(), Some("FLOW010"));
        assert_eq!(item.get("kernel").unwrap().as_str(), Some("fc"));
        assert_eq!(item.get("node").unwrap().as_str(), Some("fc1"));
    }

    #[test]
    fn pipeline_analysis_flags_budget_boundary_and_bottleneck() {
        use crate::device::Utilization;
        let stage = |name: &str, bram: f64, out_e: u64, in_e: u64, tb: bool, s: f64| {
            PipelineStageFacts {
                name: name.into(),
                device: "Stratix 10SX".into(),
                utilization: Utilization { bram_frac: bram, ..Utilization::default() },
                out_elems: out_e,
                in_elems: in_e,
                transfer_bound: tb,
                stage_s: s,
            }
        };
        // Clean 2-stage pipeline: no findings.
        let ok = vec![
            stage("net.s0", 0.5, 100, 10, false, 1e-3),
            stage("net.s1", 0.4, 10, 100, false, 8e-4),
        ];
        assert!(analyze_pipeline(&ok).is_clean(true));

        // Over-budget stage 1 names the resource + overshoot and carries
        // the stage span.
        let over = vec![
            stage("net.s0", 0.5, 100, 10, false, 1e-3),
            stage("net.s1", 1.25, 10, 100, false, 8e-4),
        ];
        let rep = analyze_pipeline(&over);
        let d = rep.errors().next().expect("FLOW053 emitted");
        assert_eq!(d.lint.code(), "FLOW053");
        assert_eq!(d.span.stage, Some(1));
        assert!(d.message.contains("BRAM"), "{}", d.message);
        assert!(d.message.contains("25%"), "{}", d.message);

        // Boundary element mismatch between stages is FLOW054.
        let torn = vec![
            stage("net.s0", 0.5, 100, 10, false, 1e-3),
            stage("net.s1", 0.4, 10, 99, false, 8e-4),
        ];
        let rep = analyze_pipeline(&torn);
        assert_eq!(rep.errors().next().unwrap().lint.code(), "FLOW054");

        // Transfer-bound: only the bottleneck stage warns.
        let tb_not_bottleneck = vec![
            stage("net.s0", 0.5, 100, 10, false, 1e-3),
            stage("net.s1", 0.4, 10, 100, true, 8e-4),
        ];
        assert!(analyze_pipeline(&tb_not_bottleneck).is_clean(true));
        let tb_bottleneck = vec![
            stage("net.s0", 0.5, 100, 10, false, 1e-3),
            stage("net.s1", 0.4, 10, 100, true, 2e-3),
        ];
        let rep = analyze_pipeline(&tb_bottleneck);
        assert!(!rep.is_clean(true));
        assert!(rep.is_clean(false), "FLOW055 is a warning, not an error");
        assert_eq!(rep.diagnostics[0].lint.code(), "FLOW055");
        assert_eq!(rep.diagnostics[0].span.stage, Some(1));
    }
}
