//! Channel deadlock analysis (FLOW001–FLOW008).
//!
//! A channelized program (§IV-E) deadlocks statically when its FIFO
//! topology is cyclic (no kernel in the cycle can ever fire), or
//! dynamically when some channel's per-frame writes and reads do not
//! balance: a surplus producer eventually blocks on a full FIFO, a
//! surplus consumer blocks on an empty one. Both are decidable here
//! because kernels stream whole feature maps with statically-known
//! element counts, so we prove for every channel
//!
//! ```text
//! writes(ch) = |fmap(producer)|   reads(ch) = Σ |input| over consumers
//! ```
//!
//! balance exactly, and that the §IV-J depth rule (a buffered channel
//! covers the largest feature map it carries) holds under the recorded
//! dispatch order.

use std::collections::BTreeSet;

use crate::analysis::{Diagnostic, Lint, Span, View};

pub(crate) fn check(view: &View) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let prog = view.program;
    let g = view.graph;
    let n = prog.kernels.len();

    // FLOW004: endpoints must name kernels; dangling channels are dropped
    // from the remaining analyses.
    let channels: Vec<_> = prog
        .channels
        .iter()
        .filter(|ch| {
            let ok = ch.from_kernel < n && ch.to_kernel < n;
            if !ok {
                out.push(Diagnostic::new(
                    Lint::ChannelDangling,
                    Span::channel(ch.name.clone()),
                    format!("channel {} has a dangling endpoint", ch.name),
                ));
            }
            ok
        })
        .collect();

    // FLOW001: Kahn's algorithm over the FIFO topology; kernels left with
    // nonzero in-degree sit on a cycle and can never fire.
    let mut indeg = vec![0usize; n];
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for ch in &channels {
        if ch.from_kernel != ch.to_kernel {
            adj[ch.from_kernel].push(ch.to_kernel);
            indeg[ch.to_kernel] += 1;
        } else {
            out.push(Diagnostic::new(
                Lint::ChannelCycle,
                Span::channel(ch.name.clone()).with_kernel(prog.kernels[ch.from_kernel].name.clone()),
                format!(
                    "channel {} loops kernel {} back to itself — it can never fire",
                    ch.name, prog.kernels[ch.from_kernel].name
                ),
            ));
        }
    }
    let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut fired = 0usize;
    while let Some(&next) = ready.iter().min() {
        ready.retain(|&i| i != next);
        fired += 1;
        for &to in &adj[next] {
            indeg[to] -= 1;
            if indeg[to] == 0 {
                ready.push(to);
            }
        }
    }
    if fired != n {
        let stuck: Vec<&str> = (0..n)
            .filter(|&i| indeg[i] > 0)
            .map(|i| prog.kernels[i].name.as_str())
            .collect();
        out.push(Diagnostic::new(
            Lint::ChannelCycle,
            Span::kernel(stuck.join(", ")),
            format!(
                "channel topology is cyclic — kernels {} can never fire",
                stuck.join(", ")
            ),
        ));
        // Token counts are meaningless on a cyclic topology.
        return out;
    }

    // FLOW002/FLOW003/FLOW005: per-channel token balance, depth coverage
    // and element type.
    for ch in &channels {
        let producer = &prog.kernels[ch.from_kernel];
        let consumer = &prog.kernels[ch.to_kernel];
        if ch.elem != producer.nest.precision {
            out.push(Diagnostic::new(
                Lint::ChannelElemMismatch,
                Span::channel(ch.name.clone()).with_kernel(producer.name.clone()),
                format!(
                    "channel {} carries {} but its producer {} streams {}",
                    ch.name,
                    ch.elem.name(),
                    producer.name,
                    producer.nest.precision.name()
                ),
            ));
        }
        let out_node = view.output_node(producer.layers[0]);
        let writes = g.nodes[out_node].shape.elems() as u64;
        let reads: u64 = consumer
            .layers
            .iter()
            .flat_map(|&layer| g.nodes[layer].inputs.iter())
            .filter(|&&inp| view.producing_kernel(inp) == Some(ch.from_kernel))
            .map(|&inp| g.nodes[inp].shape.elems() as u64)
            .sum();
        if reads != 0 && reads != writes {
            out.push(Diagnostic::new(
                Lint::ChannelTokenImbalance,
                Span::channel(ch.name.clone()).with_kernel(consumer.name.clone()),
                format!(
                    "channel {} is unbalanced: {} writes {} tokens per frame but {} reads {}",
                    ch.name, producer.name, writes, consumer.name, reads
                ),
            ));
        }
        if ch.depth < writes {
            out.push(Diagnostic::new(
                Lint::ChannelUnderDepth,
                Span::channel(ch.name.clone()).with_kernel(producer.name.clone()),
                format!(
                    "channel {} depth {} cannot buffer {}'s {}-element feature map (§IV-J)",
                    ch.name, ch.depth, producer.name, writes
                ),
            ));
        }
    }

    // FLOW006/FLOW007: the channel set must mirror the graph's
    // cross-kernel edges — a missing channel starves its consumer, an
    // orphan channel fills and never drains.
    if !channels.is_empty() {
        let mut have: BTreeSet<(usize, usize)> = BTreeSet::new();
        for ch in &channels {
            have.insert((ch.from_kernel, ch.to_kernel));
        }
        let mut want: BTreeSet<(usize, usize)> = BTreeSet::new();
        for k in &prog.kernels {
            for &layer in &k.layers {
                for &inp in &g.nodes[layer].inputs {
                    if let Some(src) = view.producing_kernel(inp) {
                        if src != k.id {
                            want.insert((src, k.id));
                        }
                    }
                }
            }
        }
        for &(a, b) in want.difference(&have) {
            out.push(Diagnostic::new(
                Lint::ChannelMissing,
                Span::kernel(prog.kernels[b].name.clone()),
                format!(
                    "graph edge {} → {} has no channel",
                    prog.kernels[a].name, prog.kernels[b].name
                ),
            ));
        }
        for &(a, b) in have.difference(&want) {
            let name = channels
                .iter()
                .find(|ch| (ch.from_kernel, ch.to_kernel) == (a, b))
                .map(|ch| ch.name.clone())
                .unwrap_or_default();
            out.push(Diagnostic::new(
                Lint::ChannelOrphan,
                Span::channel(name),
                format!(
                    "channel {} → {} matches no graph edge",
                    prog.kernels[a].name, prog.kernels[b].name
                ),
            ));
        }
    }

    // FLOW008: a kernel none of whose layer outputs reach a consumer or
    // the graph output computes a value nobody reads.
    for k in &prog.kernels {
        let live = k.layers.iter().any(|&layer| {
            let out_node = view.output_node(layer);
            out_node == g.output || !view.consumers[out_node].is_empty()
        });
        if !live {
            out.push(Diagnostic::new(
                Lint::DeadKernel,
                Span::kernel(k.name.clone()),
                format!("kernel {}'s output is never consumed", k.name),
            ));
        }
    }

    out
}
