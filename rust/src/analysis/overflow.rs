//! Quantized-accumulator overflow analysis (FLOW010–FLOW012).
//!
//! The int8 datapath (§VII extension) accumulates `i8 × i8` products in
//! the C `int` type ([`Precision::accum_c_type`]); symmetric quantization
//! bounds every operand code by `qmax = 127`, so after a reduction of
//! extent `R` the accumulator magnitude is at most `R · 127²`. We recover
//! `R` per layer from the graph's cost model (`macs / out_elems` — the
//! MAC tree feeding one output element) and prove that bound stays under
//! [`accum_limit`](crate::quant::accum_limit); when it cannot, the exact
//! layer is flagged. Calibrated ranges ([`calibrate_analytic`]) translate
//! the proof back to real units — the dequantized worst case under the
//! layer's `QParams` scales — and bound the fp16 stream values, whose
//! accumulators are fp32 but whose channel/stream payloads saturate at
//! the fp16 max finite value.

use crate::analysis::{Diagnostic, Lint, Span, View};
use crate::quant::{accum_limit, calibrate_analytic, Calibrator};
use crate::texpr::Precision;

/// Largest finite fp16 value: anything calibrated beyond this saturates
/// (or becomes infinity) on the stream.
pub const F16_MAX: f64 = 65504.0;

pub(crate) fn check(view: &View) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let g = view.graph;
    let needs_table = view
        .program
        .kernels
        .iter()
        .any(|k| matches!(k.nest.precision, Precision::Int8 | Precision::F16));
    if !needs_table {
        return out;
    }
    // MinMax (4σ envelope), not the percentile clip used for accuracy
    // simulation: an overflow proof must hold for the range extremes.
    let table = calibrate_analytic(g, Calibrator::MinMax);

    for k in &view.program.kernels {
        let precision = k.nest.precision;
        match precision {
            Precision::F32 => continue,
            Precision::Int8 => {
                let Some(limit) = accum_limit(precision) else { continue };
                if k.nest.macs_per_iter == 0 {
                    continue;
                }
                for &nid in &k.layers {
                    let n = &g.nodes[nid];
                    let elems = n.shape.elems() as u64;
                    if n.cost.macs == 0 || elems == 0 {
                        continue;
                    }
                    // Reduction extent: MACs feeding one output element.
                    let red = n.cost.macs / elems;
                    let bound = red as i128 * 127 * 127;
                    if bound <= limit as i128 / 2 {
                        continue;
                    }
                    // Real-unit translation of the bound under the layer's
                    // quantization scales, for the message.
                    let sx = n
                        .inputs
                        .first()
                        .map(|&i| table.activation(i).max_abs() / 127.0)
                        .unwrap_or(0.0);
                    let sw = table
                        .weight_ranges(nid)
                        .iter()
                        .map(|r| r.max_abs() / 127.0)
                        .fold(0.0f64, f64::max);
                    let real = bound as f64 * sx * sw;
                    let span = Span::kernel(k.name.clone()).with_node(n.name.clone());
                    if bound > limit as i128 {
                        out.push(Diagnostic::new(
                            Lint::AccumOverflow,
                            span,
                            format!(
                                "layer {}: int8 accumulator can reach |{}| = {} × 127² and wrap \
                                 the 32-bit limit {} (≈{:.3e} in real units)",
                                n.name, bound, red, limit, real
                            ),
                        ));
                    } else {
                        out.push(Diagnostic::new(
                            Lint::AccumMargin,
                            span,
                            format!(
                                "layer {}: int8 accumulator bound {} = {} × 127² is within 2× of \
                                 the 32-bit limit {}",
                                n.name, bound, red, limit
                            ),
                        ));
                    }
                }
            }
            Precision::F16 => {
                // fp16 accumulates in fp32; the risk is the stream value
                // itself leaving the representable fp16 range.
                for &nid in &k.layers {
                    let out_node = view.output_node(nid);
                    let max_abs = table.activation(out_node).max_abs();
                    if max_abs > F16_MAX {
                        out.push(Diagnostic::new(
                            Lint::F16RangeOverflow,
                            Span::kernel(k.name.clone()).with_node(g.nodes[out_node].name.clone()),
                            format!(
                                "layer {}: calibrated activation range ±{:.3e} exceeds the fp16 \
                                 max finite value {}",
                                g.nodes[out_node].name, max_abs, F16_MAX
                            ),
                        ));
                    }
                }
            }
        }
    }
    out
}
