//! Pass-trace consistency lints (FLOW050–FLOW052).
//!
//! Every pass declares an [`Equivalence`] obligation and the manager
//! records what it actually did ([`PassRecord`]). These lints cross-check
//! the two: a pass recorded as skipped must not report IR changes, a pass
//! whose diff moved values onto a quantization grid must have declared at
//! least grid-level equivalence (the differential harness otherwise holds
//! it to a tolerance it cannot meet), and an applied pass that matched
//! sites but changed nothing is noted as a no-op.
//!
//! [`Equivalence`]: crate::pass::Equivalence
//! [`PassRecord`]: crate::pass::PassRecord

use crate::analysis::{Diagnostic, Lint, Span};
use crate::pass::{Equivalence, PassTrace};

pub(crate) fn check(trace: &PassTrace) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for r in &trace.records {
        if r.skipped.is_some() && !r.diff.is_empty() {
            out.push(Diagnostic::new(
                Lint::TraceInconsistent,
                Span::pass(r.name.clone()),
                format!(
                    "pass {} is recorded as skipped ({}) but reports IR changes",
                    r.name,
                    r.skipped.as_deref().unwrap_or("")
                ),
            ));
        }
        let grid_moves = r.diff.quantize_nodes + r.diff.dequantize_nodes + r.diff.pairs_folded;
        if r.skipped.is_none()
            && grid_moves > 0
            && matches!(r.equivalence, Equivalence::BitExact | Equivalence::CostModelOnly)
        {
            out.push(Diagnostic::new(
                Lint::EquivalenceUnderstated,
                Span::pass(r.name.clone()),
                format!(
                    "pass {} moved {} value(s) onto a quantization grid but declares {} \
                     equivalence",
                    r.name,
                    grid_moves,
                    r.equivalence.name()
                ),
            ));
        }
        if r.skipped.is_none() && r.matched > 0 && r.diff.is_empty() {
            out.push(Diagnostic::new(
                Lint::PassNoEffect,
                Span::pass(r.name.clone()),
                format!(
                    "pass {} matched {} site(s) but recorded no IR change",
                    r.name, r.matched
                ),
            ));
        }
    }
    out
}
