//! Cycle-approximate performance simulation of generated accelerators.
//!
//! Replaces the paper's physical measurement (OpenCL event profiler on the
//! D5005, §V-C) with an explicit model. Two executors mirror the two
//! execution modes of §III:
//!
//! * [`pipelined`] — one kernel per layer, all concurrently active,
//!   activations through channels; throughput set by the slowest stage and
//!   the per-frame host round-trip.
//! * [`folded`] — parameterized kernels invoked layer-by-layer through
//!   command queues; cycles accumulate across layers plus launch overhead.
//!
//! [`engine`] adds an event-driven FIFO simulation of the pipelined mode to
//! expose channel-depth dynamics (stall behaviour of unbuffered channels,
//! §IV-E) that the analytical steady-state model cannot show.

pub mod engine;
pub mod folded;
pub mod memory;
pub mod pipelined;

use crate::aoc::{lsu, pipeline};
use crate::codegen::Kernel;
use crate::device::FpgaDevice;

/// Host-side timing constants (calibrated; see DESIGN.md §Calibration).
#[derive(Debug, Clone, Copy)]
pub struct HostModel {
    /// One OpenCL kernel enqueue + dispatch (folded mode pays this per
    /// layer invocation; §IV-F motivates autorun by this cost).
    pub launch_overhead_s: f64,
    /// Per-frame host round-trip in pipelined mode: input write + output
    /// read over PCIe + event handling. Binds small-network FPS (LeNet-5).
    pub frame_overhead_s: f64,
}

impl Default for HostModel {
    fn default() -> Self {
        HostModel { launch_overhead_s: 20e-6, frame_overhead_s: 195e-6 }
    }
}

/// Pipeline efficiency of folded (parameterized) kernels: dynamic bounds,
/// ragged tile edges, tile-turnaround and double-buffer refill stalls.
/// Calibrated against Table IV/V sustained-MAC rates (§V-F's "DSP
/// underutilization" discussion).
pub const FOLDED_EFFICIENCY: f64 = 0.30;

/// Timing of one layer (folded) or one stage (pipelined).
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub kernel: String,
    pub layer: String,
    /// Pipeline-issue cycles (compute-side).
    pub compute_cycles: f64,
    /// Bandwidth-bound cycles (memory-side).
    pub memory_cycles: f64,
    /// Whichever bound governs.
    pub cycles: f64,
}

/// Whole-accelerator performance estimate.
#[derive(Debug, Clone)]
pub struct PerformanceReport {
    pub fps: f64,
    pub frame_time_s: f64,
    /// Name of the slowest stage (pipelined) / biggest layer (folded).
    pub bottleneck: String,
    pub per_layer: Vec<LayerTiming>,
    /// Fraction of frame time spent in host overhead.
    pub host_frac: f64,
}

impl PerformanceReport {
    /// GFLOPS at this FPS for a network with the given per-frame FLOPs
    /// (§V-C's metric).
    pub fn gflops(&self, flops_per_frame: u64) -> f64 {
        self.fps * flops_per_frame as f64 / 1e9
    }
}

/// Compute/memory cycles of one kernel executing one layer's worth of work.
///
/// `out_elems`/`reduction` come from the layer (not the kernel) so a
/// parameterized kernel can be timed for each layer it serves.
pub fn kernel_cycles(
    k: &Kernel,
    dev: &FpgaDevice,
    fmax_mhz: f64,
    out_elems: u64,
    reduction: u64,
    efficiency: f64,
) -> (f64, f64) {
    let nest = &k.nest;
    let lanes = nest.total_unroll().max(1) as f64;
    let rep = pipeline::analyze(nest, &k.applied);

    // Per-iteration issue cost: II vs the sum of LSU stalls.
    let lsus = lsu::infer(nest);
    let mem_stall: f64 = lsus.iter().map(memory::scalar_cost).sum();
    let issue = (rep.ii as f64).max(mem_stall.max(1.0));

    // Zero-skipping datapaths only issue MACs for retained weights
    // (§VII #2; skip-control inefficiency folds into `efficiency`).
    let iters = (out_elems.max(1) as f64) * (reduction.max(1) as f64)
        * nest.weight_density.clamp(0.0, 1.0).max(0.01)
        / lanes;
    let mut compute = iters * issue / efficiency.clamp(0.05, 1.0);

    // Separate (unfused) epilogue: extra pass over the output through its
    // own temp-array LSUs (read + write + activation).
    if rep.separate_pass {
        compute += out_elems as f64 * 2.0;
    }

    // Bandwidth bound from real traffic (stall-inflated for bad patterns,
    // but never above what the bus physically moves).
    let traffic: f64 = nest.global_bytes_per_frame() as f64;
    let memory = memory::bandwidth_cycles(dev, fmax_mhz, traffic);

    (compute, memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::schedule::Scheduler;
    use crate::texpr::{self, LoopVar};

    fn lenet_c3_kernel(unrolled: bool) -> (Kernel, u64, u64) {
        let g = models::lenet5();
        let n = g.nodes.iter().find(|x| x.name == "c3").unwrap();
        let mut nest = texpr::lower(n, &g.nodes[n.inputs[0]].shape);
        let mut applied = crate::schedule::AppliedOpts::default();
        if unrolled {
            let mut s = Scheduler::new(&mut nest);
            s.cache_write().unwrap();
            s.fuse_epilogue().unwrap();
            s.unroll(LoopVar::InC).unwrap();
            s.unroll(LoopVar::KH).unwrap();
            s.unroll(LoopVar::KW).unwrap();
            s.applied.record(crate::schedule::OptKind::FloatOpt);
            applied = s.finish();
        }
        (
            Kernel { id: 0, name: "c3".into(), nest, applied, autorun: false, layers: vec![n.id], absorbed: vec![], group: None, queue: 0 },
            g.nodes.iter().find(|x| x.name == "c3").unwrap().shape.elems() as u64,
            150,
        )
    }

    #[test]
    fn unrolling_cuts_compute_cycles() {
        let dev = FpgaDevice::stratix10sx();
        let (base, oe, red) = lenet_c3_kernel(false);
        let (opt, _, _) = lenet_c3_kernel(true);
        let (cb, _) = kernel_cycles(&base, &dev, 218.0, oe, red, 1.0);
        let (co, _) = kernel_cycles(&opt, &dev, 218.0, oe, red, 1.0);
        assert!(cb / co > 50.0, "base {cb} vs opt {co}");
    }

    #[test]
    fn memory_bound_positive_when_traffic_exists() {
        let dev = FpgaDevice::stratix10sx();
        let (base, oe, red) = lenet_c3_kernel(false);
        let (_, m) = kernel_cycles(&base, &dev, 218.0, oe, red, 1.0);
        assert!(m > 0.0);
    }

    #[test]
    fn gflops_accounting() {
        let rep = PerformanceReport {
            fps: 1000.0,
            frame_time_s: 1e-3,
            bottleneck: "x".into(),
            per_layer: vec![],
            host_frac: 0.0,
        };
        assert!((rep.gflops(2_000_000) - 2.0).abs() < 1e-9);
    }
}
