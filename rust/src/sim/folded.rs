//! Folded-mode executor (§III): parameterized kernels are invoked layer by
//! layer from the host; output feature maps round-trip through global
//! memory between invocations; every invocation pays command-queue launch
//! overhead (channels/autorun are structurally unavailable — §IV-J).

use crate::codegen::KernelProgram;
use crate::device::FpgaDevice;

use super::{kernel_cycles, HostModel, LayerTiming, PerformanceReport, FOLDED_EFFICIENCY};

/// One layer's worth of work assigned to a kernel.
#[derive(Debug, Clone)]
pub struct LayerWork {
    pub node_id: usize,
    pub layer_name: String,
    /// Index into `program.kernels`.
    pub kernel_id: usize,
    pub out_elems: u64,
    pub reduction: u64,
}

/// Estimate folded-mode performance: layers execute sequentially.
pub fn simulate(
    prog: &KernelProgram,
    work: &[LayerWork],
    dev: &FpgaDevice,
    fmax_mhz: f64,
    host: &HostModel,
) -> PerformanceReport {
    let hz = fmax_mhz * 1e6;
    let mut per_layer = Vec::with_capacity(work.len());
    let mut total_cycles = 0.0;
    let mut worst = ("".to_string(), 0.0f64);

    for w in work {
        let k = &prog.kernels[w.kernel_id];
        // Tile-turnaround / ragged-edge stalls only afflict tiled
        // parameterized kernels; a rolled base kernel pipelines at its
        // (bad) steady II with no tile structure to refill.
        let eff = if k.nest.total_unroll() > 1 { FOLDED_EFFICIENCY } else { 1.0 };
        let (compute, memory) =
            kernel_cycles(k, dev, fmax_mhz, w.out_elems, w.reduction, eff);
        let cycles = compute.max(memory);
        if cycles > worst.1 {
            worst = (w.layer_name.clone(), cycles);
        }
        total_cycles += cycles;
        per_layer.push(LayerTiming {
            kernel: k.name.clone(),
            layer: w.layer_name.clone(),
            compute_cycles: compute,
            memory_cycles: memory,
            cycles,
        });
    }

    let launch_time = work.len() as f64 * host.launch_overhead_s;
    let compute_time = total_cycles / hz;
    let frame_time = compute_time + launch_time;
    PerformanceReport {
        fps: 1.0 / frame_time,
        frame_time_s: frame_time,
        bottleneck: worst.0,
        per_layer,
        host_frac: launch_time / frame_time,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::Kernel;
    use crate::graph::models;
    use crate::texpr;

    fn one_layer_setup() -> (KernelProgram, Vec<LayerWork>) {
        let g = models::mobilenet_v1();
        let n = g.nodes.iter().find(|n| n.name == "b0.pw").unwrap();
        let nest = texpr::lower(n, &g.nodes[n.inputs[0]].shape);
        let red = nest.reduction_size;
        let prog = KernelProgram {
            name: "t".into(),
            kernels: vec![Kernel {
                id: 0,
                name: "conv1x1".into(),
                nest,
                applied: Default::default(),
                autorun: false,
                layers: vec![n.id],
                absorbed: vec![],
                group: n.op.param_group(),
                queue: 0,
            }],
            channels: vec![],
            queues: 1,
        };
        let work = vec![LayerWork {
            node_id: n.id,
            layer_name: n.name.clone(),
            kernel_id: 0,
            out_elems: n.shape.elems() as u64,
            reduction: red,
        }];
        (prog, work)
    }

    #[test]
    fn frame_time_includes_launch_overhead() {
        let (prog, work) = one_layer_setup();
        let dev = FpgaDevice::stratix10sx();
        let host = HostModel::default();
        let rep = simulate(&prog, &work, &dev, 187.0, &host);
        assert!(rep.frame_time_s > host.launch_overhead_s);
        assert!(rep.host_frac > 0.0 && rep.host_frac < 1.0);
        assert_eq!(rep.bottleneck, "b0.pw");
    }

    #[test]
    fn doubling_work_roughly_halves_fps() {
        let (prog, mut work) = one_layer_setup();
        let dev = FpgaDevice::stratix10sx();
        let host = HostModel { launch_overhead_s: 0.0, frame_overhead_s: 0.0 };
        let rep1 = simulate(&prog, &work, &dev, 187.0, &host);
        let mut w2 = work[0].clone();
        w2.layer_name = "again".into();
        work.push(w2);
        let rep2 = simulate(&prog, &work, &dev, 187.0, &host);
        assert!((rep1.fps / rep2.fps - 2.0).abs() < 0.01);
    }

    #[test]
    fn per_layer_rows_cover_all_work() {
        let (prog, work) = one_layer_setup();
        let dev = FpgaDevice::stratix10sx();
        let rep = simulate(&prog, &work, &dev, 187.0, &HostModel::default());
        assert_eq!(rep.per_layer.len(), work.len());
    }
}
