//! Event-driven FIFO simulation of pipelined execution.
//!
//! The analytical model in [`super::pipelined`] gives the steady state; this
//! engine simulates token flow through the channel graph cycle-by-cycle (in
//! coarse element *chunks*) to expose the dynamics of §IV-E: an unbuffered
//! or too-shallow channel between stages with unequal producer/consumer
//! rates causes stalls that degrade throughput below the bottleneck bound.

use std::collections::VecDeque;

/// One pipeline stage: produces `out_tokens` tokens per frame, each taking
/// `cycles_per_token` to produce once inputs are available.
#[derive(Debug, Clone)]
pub struct Stage {
    pub name: String,
    pub out_tokens: u64,
    pub cycles_per_token: f64,
    /// Tokens of input consumed per output token (rate ratio).
    pub in_per_out: f64,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct EngineReport {
    /// Cycles between successive frame completions at steady state.
    pub steady_interval_cycles: f64,
    /// Total stall cycles summed over stages (back-pressure + starvation).
    pub stall_cycles: f64,
    /// Cycles to drain the first frame (latency).
    pub first_frame_cycles: f64,
}

/// Simulate `frames` frames through `stages` connected by FIFOs of
/// `depth_tokens` each. Token = one feature-map chunk.
pub fn simulate(stages: &[Stage], depth_tokens: u64, frames: u64) -> EngineReport {
    assert!(!stages.is_empty());
    let n = stages.len();
    // A consumer that needs k input tokens per output must be able to see
    // k tokens at once (a real unbuffered channel drains element-wise into
    // registers); clamp the FIFO capacity to the largest requirement so
    // depth=1 models "unbuffered" without deadlocking.
    let min_need = stages
        .iter()
        .map(|s| s.in_per_out.ceil() as u64)
        .max()
        .unwrap_or(1)
        .max(1);
    let depth_tokens = depth_tokens.max(min_need);
    // fifos[i] sits between stage i-1 and stage i; fifos[0] is the input.
    // Tokens carry the time they become visible to the consumer.
    let mut fifos: Vec<VecDeque<(f64, u64)>> = vec![VecDeque::new(); n + 1];
    // Source: all input tokens of all frames available immediately.
    let src_tokens = (stages[0].out_tokens as f64 * stages[0].in_per_out).ceil() as u64;
    for f in 0..frames {
        for _ in 0..src_tokens.max(1) {
            fifos[0].push_back((0.0, f));
        }
    }

    #[derive(Clone)]
    struct St {
        busy_until: f64,
        consumed_frac: f64,
        produced_in_frame: u64,
        frame: u64,
    }
    let mut state = vec![St { busy_until: 0.0, consumed_frac: 0.0, produced_in_frame: 0, frame: 0 }; n];
    let mut stalls = 0.0f64;
    let mut completions: Vec<f64> = Vec::with_capacity(frames as usize);

    let mut t = 0.0f64;
    let dt_guard = 10_000_000.0 * frames as f64;
    loop {
        let mut progressed = false;
        for i in 0..n {
            let s = &stages[i];
            let st = &mut state[i];
            if st.frame >= frames || t < st.busy_until {
                continue;
            }
            // Need in_per_out input tokens (fractionally accumulated),
            // all of which must already be visible (ready_at ≤ t).
            let need = (st.consumed_frac + s.in_per_out).floor() as u64;
            let have = fifos[i].iter().take_while(|(r, _)| *r <= t).count() as u64;
            if have < need {
                continue; // starved
            }
            // Back-pressure: output FIFO full?
            if i + 1 < n + 1 && fifos[i + 1].len() as u64 >= depth_tokens && i + 1 <= n - 1 {
                continue;
            }
            for _ in 0..need {
                fifos[i].pop_front();
            }
            st.consumed_frac = st.consumed_frac + s.in_per_out - need as f64;
            st.busy_until = t + s.cycles_per_token;
            st.produced_in_frame += 1;
            // The produced token becomes visible when the stage finishes it.
            fifos[i + 1].push_back((st.busy_until, st.frame));
            if st.produced_in_frame == s.out_tokens {
                if i == n - 1 {
                    completions.push(st.busy_until);
                }
                st.produced_in_frame = 0;
                st.frame += 1;
            }
            progressed = true;
        }
        if completions.len() as u64 >= frames {
            break;
        }
        if !progressed {
            // Advance time to the earliest busy_until strictly > t.
            let next = state
                .iter()
                .map(|s| s.busy_until)
                .filter(|&b| b > t)
                .fold(f64::INFINITY, f64::min);
            let next_token = fifos
                .iter()
                .flat_map(|f| f.iter().map(|(r, _)| *r))
                .filter(|&r| r > t)
                .fold(f64::INFINITY, f64::min);
            let next = next.min(next_token);
            if !next.is_finite() {
                // Deadlock (shouldn't happen with depth ≥ 1) — bail out.
                break;
            }
            // Count idle-but-unfinished stages as stalled over the gap.
            let idle = state.iter().filter(|s| s.frame < frames && s.busy_until <= t).count();
            stalls += (next - t) * idle as f64;
            t = next;
        }
        if t > dt_guard {
            break; // safety valve
        }
    }

    let first = completions.first().copied().unwrap_or(f64::NAN);
    let steady = if completions.len() >= 2 {
        let last = *completions.last().unwrap();
        (last - first) / (completions.len() - 1) as f64
    } else {
        first
    };
    EngineReport { steady_interval_cycles: steady, stall_cycles: stalls, first_frame_cycles: first }
}

/// Convenience: equal-rate stages from per-stage total cycles, chunked.
pub fn stages_from_cycles(names_cycles_tokens: &[(String, f64, u64)]) -> Vec<Stage> {
    let mut out = Vec::with_capacity(names_cycles_tokens.len());
    let mut prev_tokens = None::<u64>;
    for (name, cycles, tokens) in names_cycles_tokens {
        let tokens = (*tokens).max(1);
        out.push(Stage {
            name: name.clone(),
            out_tokens: tokens,
            cycles_per_token: cycles / tokens as f64,
            in_per_out: prev_tokens.map(|p| p as f64 / tokens as f64).unwrap_or(1.0),
        });
        prev_tokens = Some(tokens);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize, cycles_per_token: f64, tokens: u64) -> Vec<Stage> {
        (0..n)
            .map(|i| Stage {
                name: format!("s{i}"),
                out_tokens: tokens,
                cycles_per_token,
                in_per_out: 1.0,
            })
            .collect()
    }

    #[test]
    fn steady_interval_matches_bottleneck_for_uniform_pipeline() {
        let stages = uniform(4, 2.0, 50);
        let rep = simulate(&stages, 64, 6);
        // bottleneck: 50 tokens × 2 cycles = 100 cycles per frame
        assert!((rep.steady_interval_cycles - 100.0).abs() / 100.0 < 0.15, "{}", rep.steady_interval_cycles);
    }

    #[test]
    fn slow_stage_governs() {
        let mut stages = uniform(3, 1.0, 40);
        stages[1].cycles_per_token = 5.0; // bottleneck: 200 cycles
        let rep = simulate(&stages, 64, 6);
        assert!((rep.steady_interval_cycles - 200.0).abs() / 200.0 < 0.15, "{}", rep.steady_interval_cycles);
    }

    #[test]
    fn shallow_fifo_adds_stalls() {
        let mut stages = uniform(3, 1.0, 64);
        stages[2].cycles_per_token = 3.0;
        let deep = simulate(&stages, 64, 4);
        let shallow = simulate(&stages, 1, 4);
        assert!(
            shallow.stall_cycles > deep.stall_cycles
                || shallow.steady_interval_cycles > deep.steady_interval_cycles * 1.05,
            "shallow ({}, {}) vs deep ({}, {})",
            shallow.steady_interval_cycles,
            shallow.stall_cycles,
            deep.steady_interval_cycles,
            deep.stall_cycles
        );
    }

    #[test]
    fn latency_exceeds_interval() {
        let stages = uniform(5, 2.0, 30);
        let rep = simulate(&stages, 32, 4);
        assert!(rep.first_frame_cycles > rep.steady_interval_cycles);
    }

    #[test]
    fn rate_ratio_pipeline_completes() {
        // stage 1 produces 100 tokens, stage 2 downsamples 4:1 to 25.
        let stages = vec![
            Stage { name: "conv".into(), out_tokens: 100, cycles_per_token: 1.0, in_per_out: 1.0 },
            Stage { name: "pool".into(), out_tokens: 25, cycles_per_token: 1.0, in_per_out: 4.0 },
        ];
        let rep = simulate(&stages, 16, 3);
        assert!(rep.steady_interval_cycles.is_finite());
        assert!(rep.steady_interval_cycles >= 100.0 * 0.8);
    }
}
