//! Pipelined-mode executor (§III): one kernel per layer, all concurrently
//! active, feature maps streamed kernel-to-kernel through channels. Frame
//! *throughput* is set by the slowest stage (plus the per-frame host
//! round-trip); frame *latency* is the sum of stages.

use crate::codegen::KernelProgram;
use crate::device::FpgaDevice;
use crate::schedule::OptKind;

use super::{kernel_cycles, HostModel, LayerTiming, PerformanceReport};

/// Estimate pipelined-mode performance.
///
/// `concurrent` mirrors CE (§IV-G): with one command queue the kernels
/// serialize even though they are channel-connected; with one queue per
/// kernel they overlap and the bottleneck stage governs.
pub fn simulate(
    prog: &KernelProgram,
    dev: &FpgaDevice,
    fmax_mhz: f64,
    host: &HostModel,
) -> PerformanceReport {
    let hz = fmax_mhz * 1e6;
    let concurrent = prog.queues > 1;
    let mut per_layer = Vec::with_capacity(prog.kernels.len());
    let mut bottleneck = ("".to_string(), 0.0f64);
    let mut total_cycles = 0.0;

    for k in &prog.kernels {
        let (compute, memory) = kernel_cycles(
            k,
            dev,
            fmax_mhz,
            k.nest.out_elems,
            k.nest.reduction_size,
            1.0, // static bounds: full pipeline efficiency per stage
        );
        let cycles = compute.max(memory);
        total_cycles += cycles;
        if cycles > bottleneck.1 {
            bottleneck = (k.name.clone(), cycles);
        }
        per_layer.push(LayerTiming {
            kernel: k.name.clone(),
            layer: k.name.clone(),
            compute_cycles: compute,
            memory_cycles: memory,
            cycles,
        });
    }

    // Per-frame kernel launches: autorun kernels need none (§IV-F); the
    // rest are re-enqueued every frame, overlapping across queues under CE.
    let launches = prog.kernels.iter().filter(|k| !k.autorun).count() as f64;
    let launch_time = if concurrent {
        host.launch_overhead_s * launches / prog.queues.max(1) as f64
    } else {
        host.launch_overhead_s * launches
    };

    let compute_time = if concurrent { bottleneck.1 / hz } else { total_cycles / hz };
    let frame_time = compute_time.max(host.frame_overhead_s) + launch_time;
    let host_time = (host.frame_overhead_s - compute_time).max(0.0) + launch_time;

    PerformanceReport {
        fps: 1.0 / frame_time,
        frame_time_s: frame_time,
        bottleneck: bottleneck.0,
        per_layer,
        host_frac: host_time / frame_time,
    }
}

/// Check whether any kernel uses `OptKind::Channels` — pipelined mode
/// without channelization degenerates to global-memory hand-off.
pub fn uses_channels(prog: &KernelProgram) -> bool {
    prog.kernels.iter().any(|k| k.applied.contains(OptKind::Channels)) || !prog.channels.is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{Channel, Kernel};
    use crate::graph::models;
    use crate::schedule::Scheduler;
    use crate::texpr;

    fn lenet_prog(queues: usize) -> KernelProgram {
        let g = models::lenet5();
        let mut kernels = Vec::new();
        for (i, n) in g.nodes.iter().enumerate().skip(1) {
            if matches!(n.op, crate::graph::Op::Flatten) {
                continue;
            }
            let mut nest = texpr::lower(n, &g.nodes[n.inputs[0]].shape);
            let mut s = Scheduler::new(&mut nest);
            s.channelize("ifmap");
            s.channelize("ofmap");
            let _ = s.cache_read("weights");
            if n.op.is_compute() {
                // Optimized pipelined schedule: cached accumulation, fused
                // epilogue, reduction loops unrolled, relaxed float order.
                s.cache_write().unwrap();
                let _ = s.fuse_epilogue();
                for v in [texpr::LoopVar::InC, texpr::LoopVar::KH, texpr::LoopVar::KW] {
                    let _ = s.unroll(v);
                }
                s.applied.record(crate::schedule::OptKind::FloatOpt);
            }
            let applied = s.finish();
            kernels.push(Kernel {
                id: i,
                name: n.name.clone(),
                nest,
                applied,
                autorun: !n.op.has_weights(),
                layers: vec![n.id],
                absorbed: vec![],
                group: None,
                queue: if queues > 1 { i } else { 0 },
            });
        }
        let n = kernels.len();
        KernelProgram {
            name: "lenet5".into(),
            kernels,
            channels: (0..n - 1)
                .map(|i| Channel::f32(format!("ch{i}"), i, i + 1, 4704))
                .collect(),
            queues: if queues > 1 { n } else { 1 },
        }
    }

    #[test]
    fn concurrent_beats_serialized() {
        let dev = FpgaDevice::stratix10sx();
        let host = HostModel::default();
        let ce = simulate(&lenet_prog(99), &dev, 218.0, &host);
        let serial = simulate(&lenet_prog(1), &dev, 218.0, &host);
        assert!(ce.fps > serial.fps, "CE {} vs serial {}", ce.fps, serial.fps);
    }

    #[test]
    fn small_net_is_host_bound() {
        // LeNet-5's stages are tiny: the PCIe round-trip governs (this is
        // why the paper's LeNet lands at ~5K FPS, not 50K).
        let dev = FpgaDevice::stratix10sx();
        let host = HostModel::default();
        let rep = simulate(&lenet_prog(99), &dev, 218.0, &host);
        assert!(rep.host_frac > 0.5, "{}", rep.host_frac);
        assert!(rep.fps < 1.05 / host.frame_overhead_s);
    }

    #[test]
    fn channels_detected() {
        assert!(uses_channels(&lenet_prog(1)));
    }
}
