//! External-memory (DDR4) model: per-access cycle costs and the global
//! bandwidth bound.
//!
//! Two views, matching how real FPGA kernels lose performance (§II-B, §IV):
//!
//! * **per-iteration stall cost** — cycles the pipeline waits per loop
//!   iteration for each LSU, given its kind (cached/coalesced/replicated)
//!   and the access pattern's burst efficiency;
//! * **bandwidth bound** — total traffic divided by the bus's bytes/cycle
//!   at the achieved clock: no design can beat the §IV-J rule-1 roof.

use crate::aoc::lsu::{Lsu, LsuKind};
use crate::device::FpgaDevice;
use crate::texpr::Dir;

/// Per-iteration stall cost of one LSU, in cycles per loop iteration,
/// charged to the pipeline's effective initiation interval:
///
/// * cached / burst-coalesced — fully pipelined from BRAM or wide bursts: 0;
/// * scalar pipelined — half the pattern's burst waste is hidden by the
///   memory pipeline (0.5 consecutive, 2 strided, 8 windowed per word);
/// * read-modify-write — occupies the unit twice per iteration: 1;
/// * replicated — each unit re-fetches a burst per word.
pub fn scalar_cost(l: &Lsu) -> f64 {
    match l.kind {
        LsuKind::Cached | LsuKind::BurstCoalesced => 0.0,
        LsuKind::Pipelined => match l.dir {
            Dir::ReadWrite => 1.0,
            _ => 0.5 * l.stall_factor,
        },
        LsuKind::Replicated => 0.5 * l.stall_factor,
    }
}

/// Bytes per cycle the external memory delivers at a clock.
pub fn bytes_per_cycle(dev: &FpgaDevice, fmax_mhz: f64) -> f64 {
    dev.ext_bw_bytes_per_s / (fmax_mhz * 1e6)
}

/// Cycles to move `bytes` at the bandwidth roof.
pub fn bandwidth_cycles(dev: &FpgaDevice, fmax_mhz: f64, bytes: f64) -> f64 {
    bytes / bytes_per_cycle(dev, fmax_mhz)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lsu(kind: LsuKind, dir: Dir, stall: f64) -> Lsu {
        Lsu { buffer: "b".into(), kind, dir, width_bytes: 4, count: 1, stall_factor: stall }
    }

    #[test]
    fn cached_is_free() {
        assert_eq!(scalar_cost(&lsu(LsuKind::Cached, Dir::Read, 1.0)), 0.0);
    }

    #[test]
    fn windowed_is_expensive() {
        let w = scalar_cost(&lsu(LsuKind::Pipelined, Dir::Read, 16.0));
        let c = scalar_cost(&lsu(LsuKind::Pipelined, Dir::Read, 1.0));
        assert!(w >= 8.0 * c);
    }

    #[test]
    fn rmw_costs_a_full_cycle() {
        assert_eq!(scalar_cost(&lsu(LsuKind::Pipelined, Dir::ReadWrite, 1.0)), 1.0);
    }

    #[test]
    fn bandwidth_roof_matches_paper() {
        // §IV-J: 76.8 GB/s at 250 MHz = 307.2 bytes/cycle
        let dev = FpgaDevice::stratix10sx();
        let bpc = bytes_per_cycle(&dev, 250.0);
        assert!((bpc - 307.2).abs() < 0.5);
    }
}
