//! Compile-time shim for the PJRT/XLA bindings.
//!
//! The real `xla` crate (Rust bindings over `xla_extension`'s PJRT C API)
//! is not part of the vendored crate set, so this module mirrors exactly
//! the API surface [`crate::runtime`] consumes and fails at *runtime* with
//! a typed "backend unavailable" error instead of failing at *build* time.
//! That keeps the whole crate — including the serving coordinator, which
//! can execute on the [`crate::coordinator::SimEngine`] instead — building
//! and testable in environments without the PJRT shared library. Artifact
//! paths (`fpga-flow infer`, `serve --engine pjrt`, the runtime
//! integration tests) detect the missing backend and skip or report the
//! error cleanly.
//!
//! When the real bindings are available, delete this module and add the
//! `xla` crate to `Cargo.toml`; the call sites are source-compatible.

/// Error type matching the bindings' `{e:?}`-formatted usage.
pub struct XlaError(String);

impl XlaError {
    fn unavailable(what: &str) -> XlaError {
        XlaError(format!(
            "{what}: PJRT/XLA bindings are not available in this build \
             (the `xla` crate is stubbed; see rust/src/runtime/xla.rs)"
        ))
    }
}

impl std::fmt::Debug for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// PJRT client handle (one per process in the real bindings).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, XlaError> {
        Err(XlaError::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Parsed HLO module (text form; see `python/compile/aot.py`).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled, loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable("PjRtLoadedExecutable::execute_b"))
    }

    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Host-side literal value.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        Err(XlaError::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(XlaError::unavailable("Literal::to_vec"))
    }
}
