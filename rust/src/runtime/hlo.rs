//! Lightweight HLO-text inspector: structural statistics of the AOT
//! artifacts without a full parser — used by artifact-validation tests and
//! the CLI to sanity-check what the L2 lowering produced (e.g. that the
//! Pallas path really contains the kernel loop structure and the ref path
//! contains native convolutions).

/// Structural statistics of one HLO-text module.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HloStats {
    /// Total instruction lines (heuristic: `%name = ` bindings).
    pub instructions: usize,
    /// ENTRY computation parameters.
    pub entry_parameters: usize,
    /// `while` ops (the interpret-mode Pallas grid loops lower to these).
    pub while_loops: usize,
    /// Native convolution ops (the XLA-ref path).
    pub convolutions: usize,
    /// dot/dot-general ops (matmuls).
    pub dots: usize,
    /// fusion ops.
    pub fusions: usize,
    /// Named computations (sub-computations + entry).
    pub computations: usize,
}

/// Scan HLO text (as emitted by `python/compile/aot.py`). Instructions are
/// `name.N = shape op(...)` binding lines; computations open with `name {`.
pub fn stats(text: &str) -> HloStats {
    let mut s = HloStats::default();
    let mut in_entry = false;
    for line in text.lines() {
        let t = line.trim();
        if t.starts_with("ENTRY ") {
            in_entry = true;
            s.computations += 1;
            continue;
        }
        if t == "}" {
            in_entry = false;
            continue;
        }
        if t.ends_with('{') && !t.contains('=') && !t.starts_with("HloModule") {
            s.computations += 1;
            continue;
        }
        if t.contains(" = ") {
            s.instructions += 1;
            if in_entry && t.contains(" parameter(") {
                s.entry_parameters += 1;
            }
            if t.contains(" while(") {
                s.while_loops += 1;
            }
            if t.contains(" convolution(") {
                s.convolutions += 1;
            }
            if t.contains(" dot(") {
                s.dots += 1;
            }
            if t.contains(" fusion(") {
                s.fusions += 1;
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    fn read(name: &str) -> Option<String> {
        let dir = Manifest::default_dir();
        let p = dir.join(name);
        std::fs::read_to_string(p).ok()
    }

    #[test]
    fn ref_path_uses_native_convolutions() {
        let Some(text) = read("lenet5_ref.b1.hlo.txt") else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let s = stats(&text);
        assert!(s.convolutions >= 2, "{s:?}"); // c1 + c3
        assert!(s.dots >= 3, "{s:?}"); // f5/f6/f7
        assert_eq!(s.entry_parameters, 11, "{s:?}"); // image + 10 weights
    }

    #[test]
    fn pallas_path_contains_grid_loops_not_convs() {
        let Some(text) = read("lenet5.b1.hlo.txt") else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let s = stats(&text);
        // interpret-mode Pallas: MACs ride `dot`s inside `while` grid
        // loops; the only convolutions are the identity-filter im2col
        // patch gathers (one per conv layer).
        assert!(s.while_loops >= 1, "{s:?}");
        assert!(s.dots >= 5, "{s:?}"); // 2 conv matmuls + 3 dense
        assert_eq!(s.convolutions, 2, "{s:?}"); // patch gathers only
        assert!(s.instructions > 500, "{s:?}");
        assert_eq!(s.entry_parameters, 11, "{s:?}");
    }

    #[test]
    fn resnet_ref_has_36_convolutions() {
        let Some(text) = read("resnet34_ref.b1.hlo.txt") else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let s = stats(&text);
        assert_eq!(s.convolutions, 36, "{s:?}");
    }

    #[test]
    fn empty_text_yields_zeroes() {
        assert_eq!(stats(""), HloStats::default());
    }
}
