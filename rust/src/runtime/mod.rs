//! PJRT runtime: loads the HLO-text executables AOT-lowered from the JAX
//! L2 models (which call the L1 Pallas kernels) and runs inference on the
//! CPU PJRT client. Python never runs on this path — `make artifacts` is
//! the only python invocation in the whole system.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md: serialized protos from jax ≥ 0.5 carry
//! 64-bit ids that xla_extension 0.5.1 rejects; the text parser reassigns
//! ids).
//!
//! Weight parameters are pre-transferred to device buffers once at load
//! (`execute_b` path) so the per-request hot path moves only the image.
//!
//! In builds without the PJRT bindings the [`xla`] module is a
//! compile-time shim that reports "backend unavailable" at runtime; the
//! serving layer then runs on [`crate::coordinator::SimEngine`] replicas
//! instead (see `rust/src/runtime/xla.rs`).

pub mod hlo;
pub mod xla;

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// One entry of `artifacts/manifest.json` per network.
#[derive(Debug, Clone)]
pub struct NetworkArtifacts {
    pub name: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub weights_file: String,
    /// (file, impl, batch)
    pub executables: Vec<(String, String, usize)>,
    /// (name, shape, byte offset, byte length) per parameter, in order.
    pub params: Vec<(String, Vec<usize>, usize, usize)>,
}

/// Parsed artifacts manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub networks: Vec<NetworkArtifacts>,
    /// (file, m, k, n) matmul micro-kernels.
    pub kernels: Vec<(String, usize, usize, usize)>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "no artifacts manifest at {} ({e}); run `make artifacts` to AOT-lower the \
                 JAX models, or point REPRO_ARTIFACTS at an existing artifacts directory",
                path.display()
            )
        })?;
        let j = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("malformed manifest {}: {e}", path.display()))?;

        let mut networks = Vec::new();
        let nets = j.get("networks").and_then(Json::as_obj).ok_or_else(|| anyhow::anyhow!("manifest: no networks"))?;
        for (name, entry) in nets {
            let input_shape = entry
                .get("input_shape")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_u64).map(|v| v as usize).collect())
                .unwrap_or_default();
            let num_classes = entry.get("num_classes").and_then(Json::as_u64).unwrap_or(0) as usize;
            let weights_file = entry.get("weights_file").and_then(Json::as_str).unwrap_or("").to_string();
            let mut executables = Vec::new();
            for e in entry.get("executables").and_then(Json::as_arr).unwrap_or(&[]) {
                executables.push((
                    e.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
                    e.get("impl").and_then(Json::as_str).unwrap_or("").to_string(),
                    e.get("batch").and_then(Json::as_u64).unwrap_or(1) as usize,
                ));
            }
            let mut params = Vec::new();
            for p in entry.get("params").and_then(Json::as_arr).unwrap_or(&[]) {
                params.push((
                    p.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                    p.get("shape")
                        .and_then(Json::as_arr)
                        .map(|a| a.iter().filter_map(Json::as_u64).map(|v| v as usize).collect())
                        .unwrap_or_default(),
                    p.get("offset").and_then(Json::as_u64).unwrap_or(0) as usize,
                    p.get("nbytes").and_then(Json::as_u64).unwrap_or(0) as usize,
                ));
            }
            networks.push(NetworkArtifacts {
                name: name.clone(),
                input_shape,
                num_classes,
                weights_file,
                executables,
                params,
            });
        }

        let mut kernels = Vec::new();
        for k in j.get("kernels").and_then(Json::as_arr).unwrap_or(&[]) {
            kernels.push((
                k.get("file").and_then(Json::as_str).unwrap_or("").to_string(),
                k.get("m").and_then(Json::as_u64).unwrap_or(0) as usize,
                k.get("k").and_then(Json::as_u64).unwrap_or(0) as usize,
                k.get("n").and_then(Json::as_u64).unwrap_or(0) as usize,
            ));
        }

        Ok(Manifest { dir, networks, kernels })
    }

    pub fn network(&self, name: &str) -> Option<&NetworkArtifacts> {
        self.networks.iter().find(|n| n.name == name)
    }

    /// Default artifacts dir: `$REPRO_ARTIFACTS` or `./artifacts`.
    ///
    /// The directory is produced by `make artifacts` (the only python
    /// invocation in the system); it holds `manifest.json`, the per-network
    /// HLO-text executables and the weight blobs. [`Manifest::load`] on a
    /// missing directory reports the resolved path and that command, so a
    /// bare checkout fails with an actionable message instead of an opaque
    /// "No such file or directory".
    pub fn default_dir() -> PathBuf {
        std::env::var("REPRO_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

/// Which functional path to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Impl {
    /// Every MAC through the L1 Pallas kernels (functional verification).
    Pallas,
    /// Pure-XLA lowering (optimized CPU baseline for Table V).
    Ref,
}

impl Impl {
    pub fn tag(&self) -> &'static str {
        match self {
            Impl::Pallas => "pallas",
            Impl::Ref => "ref",
        }
    }
}

/// A compiled network executable with device-resident weights.
pub struct LoadedModel {
    pub network: String,
    pub impl_: Impl,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    exe: xla::PjRtLoadedExecutable,
    weight_buffers: Vec<xla::PjRtBuffer>,
    /// Host copies of the weights, kept for the naive literal-transfer
    /// path (`infer_via_literals`) used by the §Perf before/after bench.
    weight_host: Vec<(Vec<f32>, Vec<usize>)>,
}

/// True when the PJRT backend can actually execute (i.e. the real `xla`
/// bindings are linked and a CPU client constructs). False under the
/// compile-time stub — artifact-gated tests and benches check this so
/// they *skip* instead of failing in environments that have artifacts but
/// no backend.
pub fn backend_available() -> bool {
    xla::PjRtClient::cpu().is_ok()
}

/// The PJRT runtime: one CPU client + the artifacts manifest.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(artifacts_dir: impl AsRef<Path>) -> crate::Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest })
    }

    /// Load + compile one network executable and pre-transfer its weights.
    pub fn load(&self, network: &str, impl_: Impl, batch: usize) -> crate::Result<LoadedModel> {
        let net = self.manifest.network(network).ok_or_else(|| {
            let known: Vec<&str> = self.manifest.networks.iter().map(|n| n.name.as_str()).collect();
            anyhow::anyhow!(
                "network {network} not in {} (available: {}); re-run `make artifacts` if it \
                 was added to python/compile",
                self.manifest.dir.join("manifest.json").display(),
                known.join(", ")
            )
        })?;
        let (file, _, _) = net
            .executables
            .iter()
            .find(|(_, i, b)| i == impl_.tag() && *b == batch)
            .ok_or_else(|| {
                let have: Vec<String> = net
                    .executables
                    .iter()
                    .map(|(_, i, b)| format!("{i}/b{b}"))
                    .collect();
                anyhow::anyhow!(
                    "no {network} executable for impl={} batch={batch} (manifest has: {}); \
                     re-run `make artifacts` to lower more batch variants",
                    impl_.tag(),
                    have.join(", ")
                )
            })?;

        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compile: {e:?}"))?;

        // Load the weight blob and pre-transfer each parameter (§Perf L3:
        // the request path must move only the image, never the weights).
        let blob = std::fs::read(self.manifest.dir.join(&net.weights_file))?;
        let mut weight_buffers = Vec::with_capacity(net.params.len());
        let mut weight_host = Vec::with_capacity(net.params.len());
        for (name, shape, offset, nbytes) in &net.params {
            let bytes = blob
                .get(*offset..*offset + *nbytes)
                .ok_or_else(|| anyhow::anyhow!("weights blob too short at {name}"))?;
            let floats: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = self
                .client
                .buffer_from_host_buffer(&floats, shape, None)
                .map_err(|e| anyhow::anyhow!("transfer {name}: {e:?}"))?;
            weight_buffers.push(buf);
            weight_host.push((floats, shape.clone()));
        }

        Ok(LoadedModel {
            network: network.to_string(),
            impl_,
            batch,
            input_shape: net.input_shape.clone(),
            num_classes: net.num_classes,
            exe,
            weight_buffers,
            weight_host,
        })
    }

    /// Load a matmul micro-kernel executable (runtime hot-path bench).
    pub fn load_matmul(&self, m: usize, k: usize, n: usize) -> crate::Result<xla::PjRtLoadedExecutable> {
        let (file, ..) = self
            .manifest
            .kernels
            .iter()
            .find(|(_, mm, kk, nn)| *mm == m && *kk == k && *nn == n)
            .ok_or_else(|| anyhow::anyhow!("no matmul kernel {m}x{k}x{n}"))?;
        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compile: {e:?}"))
    }
}

impl LoadedModel {
    /// Elements of one input frame.
    pub fn frame_elems(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Run one batch: `frames` must hold `batch × frame_elems()` floats.
    /// Returns `batch × num_classes` logits.
    pub fn infer(&self, client: &xla::PjRtClient, frames: &[f32]) -> crate::Result<Vec<f32>> {
        let expect = self.batch * self.frame_elems();
        if frames.len() != expect {
            anyhow::bail!("expected {expect} floats, got {}", frames.len());
        }
        let mut dims = vec![self.batch];
        dims.extend(&self.input_shape);
        let image = client
            .buffer_from_host_buffer(frames, &dims, None)
            .map_err(|e| anyhow::anyhow!("image transfer: {e:?}"))?;

        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_buffers.len());
        args.push(&image);
        args.extend(self.weight_buffers.iter());

        let result = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }

    /// The naive execution path: rebuild every argument as a `Literal`
    /// each call (weights included) — what a straightforward port of the
    /// reference loader does. Kept as the measured "before" of the §Perf
    /// L3 log; `infer` is the optimized path.
    pub fn infer_via_literals(&self, frames: &[f32]) -> crate::Result<Vec<f32>> {
        let expect = self.batch * self.frame_elems();
        if frames.len() != expect {
            anyhow::bail!("expected {expect} floats, got {}", frames.len());
        }
        let mut dims: Vec<i64> = vec![self.batch as i64];
        dims.extend(self.input_shape.iter().map(|&d| d as i64));
        let mut args: Vec<xla::Literal> = Vec::with_capacity(1 + self.weight_host.len());
        args.push(
            xla::Literal::vec1(frames)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("image literal: {e:?}"))?,
        );
        for (floats, shape) in &self.weight_host {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            args.push(
                xla::Literal::vec1(floats)
                    .reshape(&dims)
                    .map_err(|e| anyhow::anyhow!("weight literal: {e:?}"))?,
            );
        }
        let result = self.exe.execute(&args).map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let lit = result[0][0].to_literal_sync().map_err(|e| anyhow::anyhow!("fetch: {e:?}"))?;
        let out = lit.to_tuple1().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))
    }

    /// Batched submit path: classify `count ≤ self.batch` frames through
    /// this fixed-batch executable, zero-padding the tail internally and
    /// truncating the predictions back to `count`.
    ///
    /// This is what the serving coordinator's replica workers call — the
    /// padding lives here, next to the executable whose shape demands it,
    /// instead of being re-implemented by every dispatcher.
    ///
    /// ```no_run
    /// # use tvm_fpga_flow::runtime::{Impl, Manifest, Runtime};
    /// let rt = Runtime::new(Manifest::default_dir())?;
    /// let b16 = rt.load("lenet5", Impl::Ref, 16)?;
    /// let frames = tvm_fpga_flow::data::mnist_like(5, 32, 0);
    /// // 5 live frames through the batch-16 executable → 5 predictions.
    /// let preds = b16.classify_padded(&rt.client, &frames.data, 5)?;
    /// assert_eq!(preds.len(), 5);
    /// # Ok::<(), anyhow::Error>(())
    /// ```
    pub fn classify_padded(
        &self,
        client: &xla::PjRtClient,
        frames: &[f32],
        count: usize,
    ) -> crate::Result<Vec<u32>> {
        let elems = self.frame_elems();
        if count > self.batch {
            anyhow::bail!("classify_padded: {count} frames exceed executable batch {}", self.batch);
        }
        if frames.len() != count * elems {
            anyhow::bail!(
                "classify_padded: expected {count}×{elems} = {} floats, got {}",
                count * elems,
                frames.len()
            );
        }
        if count == self.batch {
            return self.classify(client, frames);
        }
        let mut padded = vec![0f32; self.batch * elems];
        padded[..frames.len()].copy_from_slice(frames);
        let mut preds = self.classify(client, &padded)?;
        preds.truncate(count);
        Ok(preds)
    }

    /// Argmax per frame.
    pub fn classify(&self, client: &xla::PjRtClient, frames: &[f32]) -> crate::Result<Vec<u32>> {
        let logits = self.infer(client, frames)?;
        Ok(logits
            .chunks(self.num_classes)
            .map(|c| {
                c.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as u32)
                    .unwrap_or(0)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    fn pjrt_ready() -> bool {
        if !artifacts_ready() || !backend_available() {
            eprintln!("skipping: needs `make artifacts` + the real xla bindings");
            return false;
        }
        true
    }

    #[test]
    fn manifest_parses() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(Manifest::default_dir()).unwrap();
        assert!(m.network("lenet5").is_some());
        let l = m.network("lenet5").unwrap();
        assert_eq!(l.input_shape, vec![1, 32, 32]);
        assert_eq!(l.num_classes, 10);
        assert_eq!(l.params.len(), 10); // 2×conv(w,b) + 3×dense(w,b)
        assert!(!m.kernels.is_empty());
    }

    #[test]
    fn lenet_ref_and_pallas_agree_through_pjrt() {
        if !pjrt_ready() {
            return;
        }
        let rt = Runtime::new(Manifest::default_dir()).unwrap();
        let ref_model = rt.load("lenet5", Impl::Ref, 1).unwrap();
        let pal_model = rt.load("lenet5", Impl::Pallas, 1).unwrap();
        let batch = crate::data::mnist_like(1, 32, 3);
        let a = ref_model.infer(&rt.client, &batch.data).unwrap();
        let b = pal_model.infer(&rt.client, &batch.data).unwrap();
        assert_eq!(a.len(), 10);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 2e-3, "pallas {y} vs ref {x}");
        }
    }

    #[test]
    fn batch16_executable_works() {
        if !pjrt_ready() {
            return;
        }
        let rt = Runtime::new(Manifest::default_dir()).unwrap();
        let model = rt.load("lenet5", Impl::Ref, 16).unwrap();
        let batch = crate::data::mnist_like(16, 32, 4);
        let preds = model.classify(&rt.client, &batch.data).unwrap();
        assert_eq!(preds.len(), 16);
        assert!(preds.iter().all(|&p| p < 10));
    }

    #[test]
    fn wrong_input_size_errors() {
        if !pjrt_ready() {
            return;
        }
        let rt = Runtime::new(Manifest::default_dir()).unwrap();
        let model = rt.load("lenet5", Impl::Ref, 1).unwrap();
        assert!(model.infer(&rt.client, &[0.0; 7]).is_err());
    }
}
