//! Metrics (§V-C): FPS, GFLOPS, comparison accounting, plus the paper's
//! published numbers for every table so benches can print
//! ours-vs-paper side by side; also the serving-side instruments the
//! coordinator records ([`LatencyStats`], [`BatchHistogram`]).

/// FPS from a measured duration over N frames (§V-C: N = 1000).
pub fn fps(frames: u64, total_seconds: f64) -> f64 {
    frames as f64 / total_seconds
}

/// GFLOPS from FPS and per-frame FLOPs (§V-C).
pub fn gflops(fps: f64, flops_per_frame: u64) -> f64 {
    fps * flops_per_frame as f64 / 1e9
}

/// Speedup formatted the way the paper's tables do: `1604 (3.07×)`.
pub fn speedup_cell(ours: f64, theirs: f64) -> String {
    format!("{theirs:.4} ({:.2}x)", ours / theirs)
}

/// Published values from the paper, used by the table benches to print the
/// reference column and by EXPERIMENTS.md to compute deviations.
pub mod paper {
    /// Table II rows: (network, logic %, bram %, dsp %, f_max MHz).
    pub const TABLE2: [(&str, f64, f64, f64, f64); 3] = [
        ("lenet5", 25.0, 19.0, 5.0, 218.0),
        ("mobilenet_v1", 46.0, 48.0, 15.0, 187.0),
        ("resnet34", 59.0, 61.0, 16.0, 125.0),
    ];

    /// Table III rows: (network, applied optimization abbreviations).
    pub const TABLE3: [(&str, &[&str]); 3] = [
        ("lenet5", &["LU", "LF", "CW", "OF", "CH", "AR", "CE"]),
        ("mobilenet_v1", &["PK", "LU", "LT", "LF", "CW", "OF"]),
        ("resnet34", &["PK", "LU", "LT", "LF", "CW", "OF"]),
    ];

    /// Table IV rows: (network, base FPS, optimized FPS, speedup).
    pub const TABLE4: [(&str, f64, f64, f64); 3] = [
        ("lenet5", 524.0, 4917.0, 9.38),
        ("mobilenet_v1", 0.17, 30.3, 178.2),
        ("resnet34", 8.3e-3, 7.04, 846.0),
    ];

    /// Table V rows: (network, S10SX FPS, TVM-1t, TVM-56t, TF, TF-cuDNN).
    /// Note the paper's internal inconsistency: ResNet-34 is 7.04 FPS in
    /// Table IV but 4.6 FPS in Table V (we reproduce both, see
    /// EXPERIMENTS.md).
    pub const TABLE5: [(&str, f64, f64, f64, f64, f64); 3] = [
        ("lenet5", 4917.0, 2345.0, 1470.0, 1075.0, 1604.0),
        ("mobilenet_v1", 30.3, 15.6, 84.5, 21.6, 43.7),
        ("resnet34", 4.6, 1.2, 13.7, 10.7, 31.7),
    ];

    /// §V-E comparisons.
    pub const SEC5E_DICECCO_GFLOPS: f64 = 50.0; // their 3×3 Winograd engine
    pub const SEC5E_OURS_3X3_GFLOPS: f64 = 70.4; // paper's claim for ResNet-34 3×3
    pub const SEC5E_HADJIS_GFLOPS_NORM: f64 = 0.59; // normalized LeNet-5
    pub const SEC5E_OURS_LENET_GFLOPS: f64 = 1.91;
    pub const SEC5E_DNNWEAVER_SPEEDUP: f64 = 9.22; // AlexNet vs our MobileNet
    /// FP-operation counts the paper quotes in §V-E.
    pub const SEC5E_LENET_FLOPS: f64 = 389e3;
    pub const SEC5E_MOBILENET_FLOPS: f64 = 1.11e9;
}

/// Relative deviation |ours/paper − 1| as a percentage (EXPERIMENTS.md).
pub fn deviation_pct(ours: f64, paper: f64) -> f64 {
    if paper == 0.0 {
        return f64::NAN;
    }
    (ours / paper - 1.0).abs() * 100.0
}

/// Simple latency recorder for the coordinator: p50/p95/p99 over a window.
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, micros: u64) {
        self.samples_us.push(micros);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.samples_us.is_empty() {
            return None;
        }
        let mut s = self.samples_us.clone();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        Some(s[idx.min(s.len() - 1)])
    }

    pub fn mean(&self) -> Option<f64> {
        if self.samples_us.is_empty() {
            return None;
        }
        Some(self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64)
    }

    /// Percentile over only the most recent `window` samples — a decaying
    /// signal for admission control and autoscaling, where the
    /// run-cumulative percentile would never recover after a burst.
    pub fn recent_percentile(&self, window: usize, p: f64) -> Option<u64> {
        if self.samples_us.is_empty() || window == 0 {
            return None;
        }
        let tail = &self.samples_us[self.samples_us.len().saturating_sub(window)..];
        let mut s = tail.to_vec();
        s.sort_unstable();
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        Some(s[idx.min(s.len() - 1)])
    }
}

/// Batch-size histogram for the serving coordinator: how full the dynamic
/// batcher actually ran the device-native batch dimension.
///
/// Bucket `i` counts executed batches of size `i + 1`; sizes beyond the
/// configured maximum clamp into the last bucket.
#[derive(Debug, Clone)]
pub struct BatchHistogram {
    counts: Vec<u64>,
}

impl BatchHistogram {
    /// A histogram for batch sizes `1..=max_batch`.
    pub fn new(max_batch: usize) -> BatchHistogram {
        BatchHistogram { counts: vec![0; max_batch.max(1)] }
    }

    /// Rehydrate from exported bucket counts (e.g. a
    /// [`crate::coordinator::StatsSnapshot`]'s `batch_hist`).
    pub fn from_counts(counts: Vec<u64>) -> BatchHistogram {
        BatchHistogram { counts: if counts.is_empty() { vec![0] } else { counts } }
    }

    /// Record one executed batch of `size` frames (0 is ignored).
    pub fn record(&mut self, size: usize) {
        if size == 0 {
            return;
        }
        let idx = (size - 1).min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    /// `counts()[i]` = batches of size `i + 1`.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total batches recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Compact `size×count` rendering, skipping empty buckets:
    /// `1×3 4×10 8×120`.
    pub fn render(&self) -> String {
        let cells: Vec<String> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &n)| n > 0)
            .map(|(i, n)| format!("{}\u{00d7}{n}", i + 1))
            .collect();
        if cells.is_empty() {
            "(no batches)".into()
        } else {
            cells.join(" ")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fps_and_gflops() {
        assert_eq!(fps(1000, 2.0), 500.0);
        // §V-E cross-check: 4917 FPS × 389K FLOPs ≈ 1.91 GFLOPS
        let g = gflops(4917.0, 389_000);
        assert!((g - 1.91).abs() < 0.01, "{g}");
    }

    #[test]
    fn paper_table5_sec5e_consistency() {
        // DiCecco comparison: 70.4 / 50 = 1.4× (paper: "a speedup of 1.4×")
        let s = paper::SEC5E_OURS_3X3_GFLOPS / paper::SEC5E_DICECCO_GFLOPS;
        assert!((s - 1.4).abs() < 0.01);
        // Hadjis: 1.91 / 0.59 ≈ 3.23×
        let h = paper::SEC5E_OURS_LENET_GFLOPS / paper::SEC5E_HADJIS_GFLOPS_NORM;
        assert!((h - 3.23).abs() < 0.02);
    }

    #[test]
    fn deviation() {
        assert!((deviation_pct(110.0, 100.0) - 10.0).abs() < 1e-9);
        assert!(deviation_pct(1.0, 0.0).is_nan());
    }

    #[test]
    fn batch_histogram_buckets_and_clamps() {
        let mut h = BatchHistogram::new(4);
        h.record(1);
        h.record(1);
        h.record(4);
        h.record(9); // clamps into the last bucket
        h.record(0); // ignored
        assert_eq!(h.counts(), &[2, 0, 0, 2]);
        assert_eq!(h.total(), 4);
        assert_eq!(h.render(), "1\u{00d7}2 4\u{00d7}2");
        assert_eq!(BatchHistogram::new(3).render(), "(no batches)");
    }

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyStats::default();
        for i in 1..=100 {
            l.record(i);
        }
        assert_eq!(l.percentile(50.0), Some(51)); // nearest-rank on 1..=100
        assert_eq!(l.percentile(99.0), Some(99));
        assert_eq!(l.percentile(0.0), Some(1));
        assert!((l.mean().unwrap() - 50.5).abs() < 1e-9);
        assert_eq!(LatencyStats::default().percentile(50.0), None);
    }

    #[test]
    fn recent_percentile_sees_only_the_tail() {
        let mut l = LatencyStats::default();
        for _ in 0..100 {
            l.record(1_000_000); // an old burst
        }
        for _ in 0..50 {
            l.record(100); // recovered
        }
        // Cumulative p99 is still stuck at the burst; the recent window
        // has decayed back down.
        assert_eq!(l.percentile(99.0), Some(1_000_000));
        assert_eq!(l.recent_percentile(50, 99.0), Some(100));
        // A window larger than the history uses everything.
        assert_eq!(l.recent_percentile(1_000, 50.0), Some(1_000_000));
        assert_eq!(LatencyStats::default().recent_percentile(10, 99.0), None);
    }
}
