//! Tensor-expression loop nests (§II-A): each graph op lowers to a loop
//! nest with classified memory accesses — the representation the schedule
//! primitives transform and the AOC model analyzes.
//!
//! This mirrors what TVM's default AOCL schedule emits (§IV): convolutions
//! become `for oc / oh / ow { for ic / kh / kw { acc += x*w } }` with all
//! buffers in global memory, accumulation read-modify-written in place and
//! activations computed in a *separate* adjacent loop — exactly the
//! pathologies the paper's optimizations then remove.


use crate::graph::{Activation, Node, Op, Shape};

/// Loop variable roles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopVar {
    /// Output channels / features.
    OutC,
    /// Output rows.
    OutH,
    /// Output cols.
    OutW,
    /// Input channels / features (reduction).
    InC,
    /// Filter rows (reduction).
    KH,
    /// Filter cols (reduction).
    KW,
}

impl LoopVar {
    pub fn name(&self) -> &'static str {
        match self {
            LoopVar::OutC => "oc",
            LoopVar::OutH => "oh",
            LoopVar::OutW => "ow",
            LoopVar::InC => "ic",
            LoopVar::KH => "kh",
            LoopVar::KW => "kw",
        }
    }
}

/// One loop level. `unroll` is the replication factor the schedule applied
/// (1 = rolled). After strip-mining, `extent` stays the full trip count and
/// `unroll` divides it (the paper only fully unrolls strip-mined inners,
/// §IV-A/B, so factor == inner extent).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Loop {
    pub var: LoopVar,
    pub extent: u64,
    pub unroll: u64,
    pub reduction: bool,
    /// Extent is a runtime kernel argument (parameterized kernels, §IV-H).
    pub dynamic: bool,
}

/// Memory spaces of the OpenCL device model (§II-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemSpace {
    /// External DDR4 through LSUs.
    Global,
    /// On-chip BRAM.
    Local,
    /// Registers.
    Private,
    /// OpenCL channel (kernel-to-kernel FIFO, §IV-E).
    Channel,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    Read,
    Write,
    /// Read-modify-write (global accumulation — the II killer, §IV).
    ReadWrite,
}

/// Address pattern with respect to the innermost unrolled loop — decides
/// which LSU AOC infers (§II-B: coalesced/burst-coalesced vs replicated).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pattern {
    /// Stride-1, aligned: coalescable into one wide access.
    Consecutive,
    /// Fixed non-unit stride: replicated LSUs under unrolling.
    Strided,
    /// Data-dependent / windowed: replicated LSUs + arbitration.
    Windowed,
}

/// One memory access in the loop body.
#[derive(Debug, Clone, PartialEq)]
pub struct Access {
    pub buffer: String,
    pub space: MemSpace,
    pub dir: Dir,
    pub pattern: Pattern,
    /// Which loop vars index this buffer (unrolling one of them replicates
    /// or widens the access).
    pub indexed_by: Vec<LoopVar>,
    /// Bytes touched per frame through this access before caching
    /// (traffic, counting re-reads).
    pub bytes_per_frame: u64,
    /// Size of the underlying array in bytes (what a cache/stash must hold).
    pub array_bytes: u64,
    /// Element-type override for cross-domain kernels (quantize boundaries
    /// read f32 and write int8 in the same nest). `None` = the nest's
    /// datapath precision; a pinned access is exempt from
    /// `Scheduler::quantize`'s byte rescaling.
    pub elem: Option<Precision>,
}

/// Arithmetic precision of a kernel's datapath — the paper's future-work
/// §VII extension #1 ("quantized networks that reducing bit precision for
/// weight/activation representation can be supported") and the §V-F
/// mitigation ("using reduced precision arithmetic to fit more operations
/// per DSP and alleviate memory requirements").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    #[default]
    F32,
    F16,
    Int8,
}

impl Precision {
    /// Bytes per element.
    pub fn bytes(&self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::F16 => 2,
            Precision::Int8 => 1,
        }
    }

    /// MACs one Stratix-10 DSP performs per cycle at this precision
    /// (hard fp32 FMAC = 1; fp16 packs 2; the 18×19 multiplier pair packs
    /// 2 int8 MACs and the adders ride the same block).
    pub fn macs_per_dsp(&self) -> u64 {
        match self {
            Precision::F32 => 1,
            Precision::F16 => 2,
            Precision::Int8 => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "fp32",
            Precision::F16 => "fp16",
            Precision::Int8 => "int8",
        }
    }

    /// OpenCL element type of a buffer/channel at this precision.
    pub fn c_type(&self) -> &'static str {
        match self {
            Precision::F32 => "float",
            Precision::F16 => "half",
            Precision::Int8 => "char",
        }
    }

    /// OpenCL type of a MAC accumulator at this precision: int8 MACs
    /// widen into a 32-bit integer and fp16 products accumulate in single
    /// precision (the standard mixed-precision DSP configuration — and
    /// what the quantized reference executor models), so only the operand
    /// stream narrows, never the running sum.
    pub fn accum_c_type(&self) -> &'static str {
        match self {
            Precision::F32 | Precision::F16 => "float",
            Precision::Int8 => "int",
        }
    }

    /// Parse a CLI/user spelling.
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" | "fp32" | "float" => Some(Precision::F32),
            "f16" | "fp16" | "half" => Some(Precision::F16),
            "int8" | "i8" | "char" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Every supported precision, widest first.
    pub fn all() -> [Precision; 3] {
        [Precision::F32, Precision::F16, Precision::Int8]
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Post-reduction elementwise work attached to the nest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Epilogue {
    Activation(Activation),
    BatchNormFold,
    BiasAdd,
}

/// A lowered loop nest for one graph node.
#[derive(Debug, Clone)]
pub struct LoopNest {
    pub node_id: usize,
    pub name: String,
    pub loops: Vec<Loop>,
    pub accesses: Vec<Access>,
    /// MACs per innermost iteration (1 for conv/dense, 0 for pool etc).
    pub macs_per_iter: u64,
    /// Output elements per frame.
    pub out_elems: u64,
    /// Reduction trip count per output element.
    pub reduction_size: u64,
    /// Epilogue ops; `separate_epilogue == true` means they run in an
    /// adjacent loop with a global temporary (TVM default — pathology #1 of
    /// §IV), `false` means fused into the reduction (LF applied).
    pub epilogue: Vec<Epilogue>,
    pub separate_epilogue: bool,
    /// Accumulator lives in global memory (TVM default, pathology #3) until
    /// cached writes (§IV-D) move it to a private register.
    pub accum_space: MemSpace,
    /// Datapath precision (fp32 unless the schedule quantizes, §VII).
    pub precision: Precision,
    /// Weight density for zero-skipping datapaths (1.0 = dense, §VII #2).
    pub weight_density: f64,
}

impl LoopNest {
    /// Total unroll replication = product of per-loop unroll factors —
    /// the number of parallel MAC lanes AOC instantiates (§IV-A).
    pub fn total_unroll(&self) -> u64 {
        self.loops.iter().map(|l| l.unroll).product()
    }

    /// Unroll product over reduction loops only.
    pub fn reduction_unroll(&self) -> u64 {
        self.loops.iter().filter(|l| l.reduction).map(|l| l.unroll).product()
    }

    /// Innermost loop with unroll > 1, if any.
    pub fn innermost_unrolled(&self) -> Option<&Loop> {
        self.loops.iter().rev().find(|l| l.unroll > 1)
    }

    /// Global-memory bytes moved per frame given current access spaces.
    pub fn global_bytes_per_frame(&self) -> u64 {
        self.accesses
            .iter()
            .filter(|a| a.space == MemSpace::Global)
            .map(|a| if a.dir == Dir::ReadWrite { 2 * a.bytes_per_frame } else { a.bytes_per_frame })
            .sum()
    }

    pub fn find_loop(&self, var: LoopVar) -> Option<&Loop> {
        self.loops.iter().find(|l| l.var == var)
    }

    pub fn find_loop_mut(&mut self, var: LoopVar) -> Option<&mut Loop> {
        self.loops.iter_mut().find(|l| l.var == var)
    }
}

/// Lower one graph node to its naive (TVM-default) loop nest.
pub fn lower(node: &Node, input_shape: &Shape) -> LoopNest {
    let out_elems = node.shape.elems() as u64;
    let out_bytes = node.cost.out_bytes;
    let name = format!("{}_{}", node.name, node.op.mnemonic());

    let mk_loop = |var, extent, reduction| Loop { var, extent, unroll: 1, reduction, dynamic: false };

    match &node.op {
        Op::Conv2d { out_channels, kernel, stride, .. } => {
            let (cin, _, _) = input_shape.chw().expect("conv input CHW");
            let (oc, oh, ow) = node.shape.chw().expect("conv output CHW");
            debug_assert_eq!(oc, *out_channels);
            let k = *kernel as u64;
            let loops = vec![
                mk_loop(LoopVar::OutC, oc as u64, false),
                mk_loop(LoopVar::OutH, oh as u64, false),
                mk_loop(LoopVar::OutW, ow as u64, false),
                mk_loop(LoopVar::InC, cin as u64, true),
                mk_loop(LoopVar::KH, k, true),
                mk_loop(LoopVar::KW, k, true),
            ];
            let reduction_size = cin as u64 * k * k;
            let accesses = vec![
                Access {
                    buffer: "ifmap".into(),
                    space: MemSpace::Global,
                    dir: Dir::Read,
                    // 1×1/s1 convs scan the fmap linearly (coalesced);
                    // K>1/s1 windows replay rows (strided); strided convs
                    // skip (windowed) — decides LSU type + stall (§II-B).
                    pattern: conv_ifmap_pattern(*kernel, *stride),
                    indexed_by: vec![LoopVar::InC, LoopVar::KH, LoopVar::KW, LoopVar::OutH, LoopVar::OutW],
                    bytes_per_frame: out_elems / oc as u64 * reduction_size * 4,
                    array_bytes: input_shape.bytes() as u64,
                    elem: None,
                },
                Access {
                    buffer: "weights".into(),
                    space: MemSpace::Global,
                    dir: Dir::Read,
                    pattern: Pattern::Consecutive,
                    indexed_by: vec![LoopVar::OutC, LoopVar::InC, LoopVar::KH, LoopVar::KW],
                    bytes_per_frame: node.cost.params * 4,
                    array_bytes: node.cost.params * 4,
                    elem: None,
                },
                Access {
                    buffer: "ofmap".into(),
                    space: MemSpace::Global,
                    dir: Dir::ReadWrite, // naive global accumulation
                    pattern: Pattern::Consecutive,
                    indexed_by: vec![LoopVar::OutC, LoopVar::OutH, LoopVar::OutW],
                    bytes_per_frame: out_bytes,
                    array_bytes: out_bytes,
                    elem: None,
                },
            ];
            LoopNest {
                node_id: node.id,
                name,
                loops,
                accesses,
                macs_per_iter: 1,
                out_elems,
                reduction_size,
                epilogue: epilogue_of(&node.op),
                separate_epilogue: !epilogue_of(&node.op).is_empty(),
                accum_space: MemSpace::Global,
                precision: Precision::F32,
                weight_density: 1.0,
            }
        }
        Op::DepthwiseConv2d { kernel, stride, .. } => {
            let (c, oh, ow) = node.shape.chw().expect("dw output CHW");
            let k = *kernel as u64;
            let loops = vec![
                mk_loop(LoopVar::OutC, c as u64, false),
                mk_loop(LoopVar::OutH, oh as u64, false),
                mk_loop(LoopVar::OutW, ow as u64, false),
                mk_loop(LoopVar::KH, k, true),
                mk_loop(LoopVar::KW, k, true),
            ];
            let reduction_size = k * k;
            let accesses = vec![
                Access {
                    buffer: "ifmap".into(),
                    space: MemSpace::Global,
                    dir: Dir::Read,
                    pattern: conv_ifmap_pattern(*kernel, *stride),
                    indexed_by: vec![LoopVar::OutC, LoopVar::KH, LoopVar::KW, LoopVar::OutH, LoopVar::OutW],
                    bytes_per_frame: out_elems * reduction_size * 4,
                    array_bytes: input_shape.bytes() as u64,
                    elem: None,
                },
                Access {
                    buffer: "weights".into(),
                    space: MemSpace::Global,
                    dir: Dir::Read,
                    pattern: Pattern::Consecutive,
                    indexed_by: vec![LoopVar::OutC, LoopVar::KH, LoopVar::KW],
                    bytes_per_frame: node.cost.params * 4,
                    array_bytes: node.cost.params * 4,
                    elem: None,
                },
                Access {
                    buffer: "ofmap".into(),
                    space: MemSpace::Global,
                    dir: Dir::ReadWrite,
                    pattern: Pattern::Consecutive,
                    indexed_by: vec![LoopVar::OutC, LoopVar::OutH, LoopVar::OutW],
                    bytes_per_frame: out_bytes,
                    array_bytes: out_bytes,
                    elem: None,
                },
            ];
            LoopNest {
                node_id: node.id,
                name,
                loops,
                accesses,
                macs_per_iter: 1,
                out_elems,
                reduction_size,
                epilogue: epilogue_of(&node.op),
                separate_epilogue: !epilogue_of(&node.op).is_empty(),
                accum_space: MemSpace::Global,
                precision: Precision::F32,
                weight_density: 1.0,
            }
        }
        Op::Dense { out_features, .. } => {
            let cin = input_shape.elems() as u64;
            let loops = vec![
                mk_loop(LoopVar::OutC, *out_features as u64, false),
                mk_loop(LoopVar::InC, cin, true),
            ];
            let accesses = vec![
                Access {
                    buffer: "ifmap".into(),
                    space: MemSpace::Global,
                    dir: Dir::Read,
                    pattern: Pattern::Consecutive,
                    indexed_by: vec![LoopVar::InC],
                    bytes_per_frame: cin * 4 * *out_features as u64,
                    array_bytes: cin * 4,
                    elem: None,
                },
                Access {
                    buffer: "weights".into(),
                    space: MemSpace::Global,
                    dir: Dir::Read,
                    pattern: Pattern::Consecutive,
                    indexed_by: vec![LoopVar::OutC, LoopVar::InC],
                    bytes_per_frame: node.cost.params * 4,
                    array_bytes: node.cost.params * 4,
                    elem: None,
                },
                Access {
                    buffer: "ofmap".into(),
                    space: MemSpace::Global,
                    dir: Dir::ReadWrite,
                    pattern: Pattern::Consecutive,
                    indexed_by: vec![LoopVar::OutC],
                    bytes_per_frame: out_bytes,
                    array_bytes: out_bytes,
                    elem: None,
                },
            ];
            LoopNest {
                node_id: node.id,
                name,
                loops,
                accesses,
                macs_per_iter: 1,
                out_elems,
                reduction_size: cin,
                epilogue: epilogue_of(&node.op),
                separate_epilogue: !epilogue_of(&node.op).is_empty(),
                accum_space: MemSpace::Global,
                precision: Precision::F32,
                weight_density: 1.0,
            }
        }
        Op::MaxPool { kernel, .. } | Op::AvgPool { kernel, .. } => {
            let (c, oh, ow) = node.shape.chw().expect("pool output CHW");
            let k = *kernel as u64;
            elementwise_nest(node, name, vec![
                mk_loop(LoopVar::OutC, c as u64, false),
                mk_loop(LoopVar::OutH, oh as u64, false),
                mk_loop(LoopVar::OutW, ow as u64, false),
                mk_loop(LoopVar::KH, k, true),
                mk_loop(LoopVar::KW, k, true),
            ], out_elems, k * k, out_elems * k * k * 4)
        }
        Op::GlobalAvgPool => {
            let (c, h, w) = input_shape.chw().expect("gap input CHW");
            elementwise_nest(node, name, vec![
                mk_loop(LoopVar::OutC, c as u64, false),
                mk_loop(LoopVar::KH, h as u64, true),
                mk_loop(LoopVar::KW, w as u64, true),
            ], out_elems, (h * w) as u64, (c * h * w) as u64 * 4)
        }
        // Grid boundaries are cross-domain: a quantize reads f32 and
        // writes the narrow stream, a dequantize reads the narrow stream
        // and writes f32. Pin the per-access element types so blanket
        // precision rescaling can never touch the fixed side.
        Op::Quantize { .. } | Op::Dequantize { .. } => {
            let loops = match node.shape.chw() {
                Some((c, h, w)) => vec![
                    mk_loop(LoopVar::OutC, c as u64, false),
                    mk_loop(LoopVar::OutH, h as u64, false),
                    mk_loop(LoopVar::OutW, w as u64, false),
                ],
                None => vec![mk_loop(LoopVar::OutC, node.shape.elems() as u64, false)],
            };
            let mut nest = elementwise_nest(node, name, loops, out_elems, 1, out_bytes);
            let (in_p, out_p) = match &node.op {
                Op::Quantize { precision } => (Precision::F32, *precision),
                Op::Dequantize { precision } => (*precision, Precision::F32),
                _ => unreachable!("arm covers quantize/dequantize"),
            };
            pin_elem(&mut nest, "ifmap", in_p);
            pin_elem(&mut nest, "ofmap", out_p);
            nest
        }
        // Elementwise / helper ops: one pass over the output.
        _ => {
            let loops = match node.shape.chw() {
                Some((c, h, w)) => vec![
                    mk_loop(LoopVar::OutC, c as u64, false),
                    mk_loop(LoopVar::OutH, h as u64, false),
                    mk_loop(LoopVar::OutW, w as u64, false),
                ],
                None => vec![mk_loop(LoopVar::OutC, node.shape.elems() as u64, false)],
            };
            let read_bytes = out_bytes * if matches!(node.op, Op::Add) { 2 } else { 1 };
            elementwise_nest(node, name, loops, out_elems, 1, read_bytes)
        }
    }
}

/// Pin one buffer of a cross-domain nest to a fixed element type,
/// rescaling its (f32-basis) traffic to that width. Pinned accesses are
/// exempt from [`crate::schedule::Scheduler::quantize`].
fn pin_elem(nest: &mut LoopNest, buffer: &str, p: Precision) {
    for a in &mut nest.accesses {
        if a.buffer == buffer {
            a.bytes_per_frame = a.bytes_per_frame * p.bytes() / 4;
            a.array_bytes = a.array_bytes * p.bytes() / 4;
            a.elem = Some(p);
        }
    }
}

fn elementwise_nest(
    node: &Node,
    name: String,
    loops: Vec<Loop>,
    out_elems: u64,
    reduction_size: u64,
    read_bytes: u64,
) -> LoopNest {
    let accesses = vec![
        Access {
            buffer: "ifmap".into(),
            space: MemSpace::Global,
            dir: Dir::Read,
            pattern: Pattern::Consecutive,
            indexed_by: loops.iter().map(|l| l.var).collect(),
            bytes_per_frame: read_bytes,
            array_bytes: read_bytes,
            elem: None,
        },
        Access {
            buffer: "ofmap".into(),
            space: MemSpace::Global,
            dir: Dir::Write,
            pattern: Pattern::Consecutive,
            indexed_by: loops.iter().filter(|l| !l.reduction).map(|l| l.var).collect(),
            bytes_per_frame: node.cost.out_bytes,
            array_bytes: node.cost.out_bytes,
            elem: None,
        },
    ];
    LoopNest {
        node_id: node.id,
        name,
        loops,
        accesses,
        macs_per_iter: 0,
        out_elems,
        reduction_size,
        epilogue: vec![],
        separate_epilogue: false,
        accum_space: if reduction_size > 1 { MemSpace::Global } else { MemSpace::Private },
        precision: Precision::F32,
        weight_density: 1.0,
    }
}

/// Ifmap LSU pattern class for a conv of the given geometry: pointwise
/// convs scan linearly; stride-1 windows replay rows at a fixed stride;
/// strided windows defeat coalescing entirely.
pub fn conv_ifmap_pattern(kernel: usize, stride: usize) -> Pattern {
    if kernel == 1 && stride == 1 {
        Pattern::Consecutive
    } else if stride == 1 {
        Pattern::Strided
    } else {
        Pattern::Windowed
    }
}

fn epilogue_of(op: &Op) -> Vec<Epilogue> {
    let mut e = Vec::new();
    match op {
        Op::Conv2d { bias, activation, .. } | Op::DepthwiseConv2d { bias, activation, .. } => {
            if *bias {
                e.push(Epilogue::BiasAdd);
            }
            if *activation != Activation::None {
                e.push(Epilogue::Activation(*activation));
            }
        }
        Op::Dense { bias, activation, .. } => {
            if *bias {
                e.push(Epilogue::BiasAdd);
            }
            if *activation != Activation::None {
                e.push(Epilogue::Activation(*activation));
            }
        }
        _ => {}
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn conv_nest_structure() {
        let g = models::lenet5();
        let c1 = &g.nodes[1];
        let nest = lower(c1, &g.nodes[0].shape);
        assert_eq!(nest.loops.len(), 6);
        assert_eq!(nest.reduction_size, 25); // 1 in-channel × 5×5
        assert_eq!(nest.total_unroll(), 1);
        assert_eq!(nest.accum_space, MemSpace::Global);
        assert!(nest.separate_epilogue, "tanh lowers to an adjacent loop by default");
        assert_eq!(nest.out_elems, 6 * 28 * 28);
    }

    #[test]
    fn dense_nest_structure() {
        let g = models::lenet5();
        let f5 = g.nodes.iter().find(|n| n.name == "f5").unwrap();
        let flat = &g.nodes[f5.inputs[0]];
        let nest = lower(f5, &flat.shape);
        assert_eq!(nest.loops.len(), 2);
        assert_eq!(nest.reduction_size, 400);
        assert_eq!(nest.macs_per_iter, 1);
    }

    #[test]
    fn global_traffic_counts_rmw_twice() {
        let g = models::lenet5();
        let c1 = &g.nodes[1];
        let nest = lower(c1, &g.nodes[0].shape);
        let ofmap = nest.accesses.iter().find(|a| a.buffer == "ofmap").unwrap();
        assert_eq!(ofmap.dir, Dir::ReadWrite);
        let total = nest.global_bytes_per_frame();
        assert!(total > 2 * ofmap.bytes_per_frame);
    }

    #[test]
    fn pool_has_no_macs() {
        let g = models::resnet34();
        let mp = g.nodes.iter().find(|n| n.name == "maxpool").unwrap();
        let nest = lower(mp, &g.nodes[mp.inputs[0]].shape);
        assert_eq!(nest.macs_per_iter, 0);
        assert_eq!(nest.reduction_size, 9);
    }

    #[test]
    fn strided_conv_window_pattern() {
        let g = models::resnet34();
        let c1 = &g.nodes[1]; // 7×7 stride-2
        let nest = lower(c1, &g.nodes[0].shape);
        let ifmap = nest.accesses.iter().find(|a| a.buffer == "ifmap").unwrap();
        assert_eq!(ifmap.pattern, Pattern::Windowed);
    }
}
