//! # tvm-fpga-flow
//!
//! Reproduction of *"A Compilation Flow for the Generation of CNN Inference
//! Accelerators on FPGAs"* (Chung & Abdelrahman, 2022) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper compiles a frozen CNN graph through TVM into Intel-OpenCL
//! kernels, applies nine automated optimizations (its Table I), and
//! synthesizes a Stratix 10SX bitstream with Intel AOC. This crate rebuilds
//! the whole flow with the FPGA toolchain replaced by explicit models (no
//! FPGA in this environment — see DESIGN.md §Substitutions):
//!
//! * [`graph`] — Relay-analog CNN graph IR + the three evaluation networks
//!   (LeNet-5, MobileNetV1, ResNet-34).
//! * [`texpr`] — tensor-expression loop nests lowered from graph ops.
//! * [`schedule`] — scheduling primitives: unroll, strip-mine/tile, fuse,
//!   cache-write, parameterize (the paper's §IV-A..D, H).
//! * [`codegen`] — OpenCL-like kernel IR + pseudo-OpenCL source emission.
//! * [`aoc`] — the "AOC compiler" model: LSU inference, loop-pipelining II
//!   analysis, ALUT/FF/DSP/BRAM estimation, f_max prediction.
//! * [`device`] — Stratix 10SX D5005 device model + baseline platforms.
//! * [`sim`] — cycle-approximate dataflow simulator for pipelined
//!   (channels, autorun, concurrent queues) and folded (parameterized
//!   kernels) execution.
//! * [`flow`] — the end-to-end compilation flow (the paper's contribution):
//!   pattern-based optimization application (Table I) + legality rules
//!   (§IV-J) + compile driver.
//! * [`dse`] — design-space explorer over unroll/tile factors (the paper's
//!   future-work §IV-J automated).
//! * [`runtime`] — PJRT runtime: loads `artifacts/*.hlo.txt` AOT-lowered
//!   from JAX (L2) with Pallas kernels (L1) and executes inference on CPU.
//!   Python never runs on this path.
//! * [`coordinator`] — tokio inference server: request router, dynamic
//!   batcher, command-queue execution, metrics.
//! * [`data`] — synthetic dataset generation (deterministic).
//! * [`metrics`] — FPS/GFLOPS accounting and table formatting (§V-C).
//!
//! ## Quickstart
//!
//! ```no_run
//! use tvm_fpga_flow::flow::{Flow, Mode, OptLevel};
//! use tvm_fpga_flow::graph::models;
//!
//! let net = models::lenet5();
//! let acc = Flow::new().compile(&net, Mode::Pipelined, OptLevel::Optimized).unwrap();
//! println!("fmax = {:.0} MHz, FPS = {:.0}", acc.synthesis.fmax_mhz, acc.performance.fps);
//! ```

pub mod aoc;
pub mod codegen;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod dse;
pub mod flow;
pub mod graph;
pub mod metrics;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod texpr;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
