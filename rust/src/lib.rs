//! # tvm-fpga-flow
//!
//! Reproduction of *"A Compilation Flow for the Generation of CNN Inference
//! Accelerators on FPGAs"* (Chung & Abdelrahman, 2022) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper compiles a frozen CNN graph through TVM into Intel-OpenCL
//! kernels, applies nine automated optimizations (its Table I), and
//! synthesizes a Stratix 10SX bitstream with Intel AOC. This crate rebuilds
//! the whole flow with the FPGA toolchain replaced by explicit models (no
//! FPGA in this environment — see DESIGN.md §Substitutions):
//!
//! * [`graph`] — Relay-analog CNN graph IR + the three evaluation networks
//!   (LeNet-5, MobileNetV1, ResNet-34).
//! * [`texpr`] — tensor-expression loop nests lowered from graph ops.
//! * [`schedule`] — scheduling primitives: unroll, strip-mine/tile, fuse,
//!   cache-write, parameterize (the paper's §IV-A..D, H).
//! * [`codegen`] — OpenCL-like kernel IR + pseudo-OpenCL source emission.
//! * [`aoc`] — the "AOC compiler" model: LSU inference, loop-pipelining II
//!   analysis, ALUT/FF/DSP/BRAM estimation, f_max prediction.
//! * [`device`] — named [`device::Target`] registry (Stratix 10SX D5005,
//!   Arria 10 GX, Agilex 7) + baseline platforms; each target carries its
//!   §IV-J legality clock and bandwidth roof.
//! * [`sim`] — cycle-approximate dataflow simulator for pipelined
//!   (channels, autorun, concurrent queues) and folded (parameterized
//!   kernels) execution.
//! * [`pass`] — the unified optimization-pass pipeline: every Table I
//!   optimization (and the Q/VT/SP extensions) is a registered
//!   [`pass::GraphPass`] or [`pass::SchedulePass`] executed by the
//!   [`pass::PassManager`] over a declarative [`pass::Pipeline`], with a
//!   report-visible [`pass::PassTrace`] (what matched, what changed, why
//!   skipped) behind `fpga-flow explain` and `report_json.pass_trace`.
//! * [`flow`] — the end-to-end compilation flow (the paper's contribution):
//!   [`flow::OptConfig`] selects passes into the mode pipelines, the
//!   §IV-J legality rules gate them, and the staged
//!   [`flow::Compiler`]/[`flow::CompileSession`] API runs the manager
//!   with memoized synthesis.
//! * [`quant`] — quantization-aware compilation (§VII future-work #1):
//!   calibration (min-max / percentile, empirical or analytic), symmetric
//!   per-tensor/per-channel fixed-point schemes, quantize/dequantize graph
//!   rewriting, value-accurate quantized execution and top-1 accuracy
//!   accounting. Drives `CompileSession::with_quantization` and the DSE's
//!   precision axis.
//! * [`dse`] — design-space explorer over unroll/tile factors *and
//!   datapath precision* (the paper's future-work §IV-J automated);
//!   reports its synthesis-cache hit rate and an
//!   accuracy-vs-FPS-vs-resources Pareto front.
//! * [`analysis`] — static design-rule analyzer: channel-deadlock,
//!   accumulator-overflow, resource-budget, structural and pass-trace
//!   consistency diagnostics with stable `FLOW0xx` lint codes, run as the
//!   `analyze` stage between lowering and synthesis
//!   ([`flow::CompileSession::analyze`], `fpga-flow check`,
//!   `report_json.diagnostics`).
//! * [`verify`] — differential verification that the pass pipeline is
//!   semantics-preserving: a functional interpreter executes the lowered
//!   [`codegen::KernelProgram`] (channel dataflow, fused epilogues,
//!   f32/fp16/int8 datapaths) against the graph-level
//!   [`quant::Executor`] oracle — bit-exact at int8, toleranced for
//!   floats — plus a fuzzing harness with counterexample shrinking.
//!   Drives [`flow::CompileSession::verify`] and `fpga-flow verify`.
//! * [`runtime`] — PJRT runtime: loads `artifacts/*.hlo.txt` AOT-lowered
//!   from JAX (L2) with Pallas kernels (L1) and executes inference on CPU.
//!   Python never runs on this path. In builds without the PJRT bindings
//!   the [`runtime::xla`] module is a compile-time shim that reports
//!   "backend unavailable" at runtime.
//! * [`coordinator`] — dynamic-batching replica scheduler: a bounded
//!   [`coordinator::BatchQueue`] coalesces frames into device-native
//!   batches, a replica set shards them across engines (PJRT-backed or
//!   simulated accelerators, possibly compiled for different targets) with
//!   throughput-weighted routing, and overload surfaces as a typed
//!   [`coordinator::ServerError::Overloaded`].
//! * [`data`] — synthetic dataset generation (deterministic).
//! * [`metrics`] — FPS/GFLOPS accounting, paper tables, serving latency
//!   stats and the batch-size histogram (§V-C).
//! * [`obs`] — flow-wide observability: hierarchical span tracer + typed
//!   metrics registry threaded through compile stages, passes, analysis,
//!   host execution, DSE and serving; exports Chrome trace-event JSON
//!   (Perfetto) and Prometheus text (`fpga-flow profile`, `--trace-out`).
//!
//! ## Quickstart
//!
//! The staged API compiles one stage at a time; each stage returns a typed
//! artifact you can inspect, cache and re-enter:
//!
//! ```
//! use tvm_fpga_flow::flow::{Compiler, ModeChoice};
//! use tvm_fpga_flow::graph::models;
//!
//! let net = models::lenet5();
//! let compiler = Compiler::for_target("stratix10sx").unwrap();
//! let mut session = compiler.graph(&net).mode(ModeChoice::Auto);
//! let lowered = session.lower().unwrap();       // scheduled kernels, §IV-J checked
//! let design = lowered.synthesize().unwrap();   // AOC model, memoized by content hash
//! let acc = design.simulate().unwrap();         // performance at the routed f_max
//! assert!(design.fmax_mhz() > 0.0 && acc.performance.fps > 0.0);
//! ```
//!
//! ## Serving
//!
//! Compiled designs serve traffic through the coordinator. The demo fleet
//! below runs on simulated replicas compiled for two different targets —
//! no artifacts or PJRT build required:
//!
//! ```
//! use std::time::Duration;
//! use tvm_fpga_flow::coordinator::{EngineSpec, InferenceServer, ServerConfig, SimEngine};
//! use tvm_fpga_flow::flow::multi::ReplicaPlan;
//! use tvm_fpga_flow::graph::models;
//!
//! let net = models::lenet5();
//! let plan = ReplicaPlan::build(&net, &["stratix10sx", "agilex7"]).unwrap();
//! let replicas = SimEngine::from_plan(&plan, &net, 8)
//!     .unwrap()
//!     .into_iter()
//!     // Compress modeled time so the doc-test stays fast.
//!     .map(|e| EngineSpec::Sim(e.with_time_scale(1e4)))
//!     .collect();
//! let server = InferenceServer::start(ServerConfig {
//!     max_batch: 8,
//!     max_wait: Duration::from_micros(500),
//!     replicas,
//!     ..Default::default()
//! })
//! .unwrap();
//! let data = tvm_fpga_flow::data::mnist_like(16, 32, 1);
//! let pending: Vec<_> =
//!     (0..16).map(|i| server.infer_async(data.frame(i).to_vec()).unwrap()).collect();
//! for rx in pending {
//!     assert!(rx.recv().unwrap().unwrap() < 10);
//! }
//! let stats = server.shutdown();
//! assert_eq!(stats.completed, stats.submitted);
//! ```
//!
//! The old monolithic form, `Flow::new().compile(&net, mode, level)`, still
//! works but is **deprecated** — it is a thin shim over the session API and
//! gains neither target selection nor synthesis memoization. Migration:
//! `Flow::new()` → [`flow::Compiler::for_target`] (or
//! [`flow::Compiler::new`] with an explicit target), then either the
//! staged session chain above or the one-shot
//! [`flow::Compiler::compile`] / [`flow::Compiler::compile_with`], which
//! take the same arguments as the shims they replace.

pub mod analysis;
pub mod aoc;
pub mod codegen;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod dse;
pub mod flow;
pub mod graph;
pub mod metrics;
pub mod obs;
pub mod pass;
pub mod quant;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod texpr;
pub mod util;
pub mod verify;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
