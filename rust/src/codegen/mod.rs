//! OpenCL-like kernel IR and pseudo-OpenCL source emission.
//!
//! The flow maps graph nodes onto kernels — one per layer in pipelined
//! mode, one per (filter, stride) group in folded mode (§III, §IV-H) —
//! then the AOC model (`crate::aoc`) analyzes these kernels exactly the way
//! Intel's offline compiler analyzes real OpenCL kernels.


use crate::graph::ParamGroup;
use crate::schedule::AppliedOpts;
use crate::texpr::{LoopNest, MemSpace, Precision};

/// A channel (kernel-to-kernel FIFO) connection, §IV-E.
#[derive(Debug, Clone, PartialEq)]
pub struct Channel {
    pub name: String,
    pub from_kernel: usize,
    pub to_kernel: usize,
    /// FIFO depth in elements (user-specified; must cover the largest
    /// feature map for buffered channels, §IV-J).
    pub depth: u64,
    /// Element type carried by the FIFO (int8 streams pack 4× the elements
    /// into the same BRAM as fp32, §VII extension).
    pub elem: Precision,
}

impl Channel {
    /// An fp32 channel (the paper's setting).
    pub fn f32(name: impl Into<String>, from_kernel: usize, to_kernel: usize, depth: u64) -> Channel {
        Channel { name: name.into(), from_kernel, to_kernel, depth, elem: Precision::F32 }
    }
}

/// One generated OpenCL kernel.
#[derive(Debug, Clone)]
pub struct Kernel {
    pub id: usize,
    pub name: String,
    pub nest: LoopNest,
    pub applied: AppliedOpts,
    /// Runs without host control (§IV-F). Requires no global args.
    pub autorun: bool,
    /// Which graph nodes this kernel executes (several in folded mode).
    pub layers: Vec<usize>,
    /// Graph nodes whose BatchNorm/activation loops were absorbed into
    /// this kernel's epilogue by loop fusion (LF), in absorption order.
    /// Carried so the program remains executable stand-alone: without it a
    /// `BatchNormFold` epilogue entry names no parameters, and the
    /// `verify` interpreter could not cross-check the fused chain against
    /// the graph. Parameterized (PK) kernels keep only the representative
    /// layer's chain — member layers resolve theirs at dispatch.
    pub absorbed: Vec<usize>,
    /// Parameterized-kernel group (folded mode only).
    pub group: Option<ParamGroup>,
    /// Host command queue index (one queue per kernel = CE, §IV-G).
    pub queue: usize,
}

impl Kernel {
    /// A kernel qualifies for autorun iff it has no global-memory accesses
    /// (§IV-F: "Kernels that have no arguments (i.e., no accesses to global
    /// memory) can be declared autorun").
    pub fn autorun_eligible(&self) -> bool {
        !self.nest.accesses.iter().any(|a| a.space == MemSpace::Global)
    }

    /// Number of distinct global buffers (→ kernel arguments).
    pub fn global_args(&self) -> usize {
        let mut bufs: Vec<&str> = self
            .nest
            .accesses
            .iter()
            .filter(|a| a.space == MemSpace::Global)
            .map(|a| a.buffer.as_str())
            .collect();
        bufs.sort_unstable();
        bufs.dedup();
        bufs.len()
    }
}

/// The complete generated accelerator program: kernels + channels.
#[derive(Debug, Clone)]
pub struct KernelProgram {
    pub name: String,
    pub kernels: Vec<Kernel>,
    pub channels: Vec<Channel>,
    /// Number of host command queues (1 = serialized; one per kernel = CE).
    pub queues: usize,
}

impl KernelProgram {
    pub fn kernel_by_layer(&self, node_id: usize) -> Option<&Kernel> {
        self.kernels.iter().find(|k| k.layers.contains(&node_id))
    }

    pub fn autorun_count(&self) -> usize {
        self.kernels.iter().filter(|k| k.autorun).count()
    }

    /// Emit human-readable pseudo-OpenCL for inspection / docs — the shape
    /// of what TVM+our optimizations would hand to AOC. Buffer and channel
    /// element types follow each kernel's datapath precision, so a
    /// quantized program round-trips its dtype metadata instead of
    /// pretending everything is `float`.
    pub fn to_pseudo_opencl(&self) -> String {
        let mut out = String::new();
        for ch in &self.channels {
            out.push_str(&format!(
                "channel {} {} __attribute__((depth({})));\n",
                ch.elem.c_type(),
                ch.name,
                ch.depth
            ));
        }
        if !self.channels.is_empty() {
            out.push('\n');
        }
        for k in &self.kernels {
            out.push_str(&render_kernel(k));
            out.push('\n');
        }
        out
    }
}

fn render_kernel(k: &Kernel) -> String {
    let mut s = String::new();
    if k.autorun {
        s.push_str("__attribute__((autorun))\n");
    }
    s.push_str("__kernel void ");
    s.push_str(&k.name);
    s.push('(');
    let mut args: Vec<String> = Vec::new();
    let mut seen = std::collections::BTreeSet::new();
    for a in &k.nest.accesses {
        if a.space == MemSpace::Global && seen.insert(a.buffer.clone()) {
            // Cross-domain kernels (quantize/dequantize boundaries) pin
            // per-access element types; everything else follows the
            // kernel's datapath precision.
            let ty = a.elem.unwrap_or(k.nest.precision).c_type();
            args.push(format!("__global {ty}* restrict {}", a.buffer));
        }
    }
    if k.nest.precision == Precision::Int8 && k.nest.macs_per_iter > 0 {
        // Fixed-point datapaths dequantize the integer accumulator on the
        // way out (fp16 accumulates in float and needs no scale).
        args.push("const float dequant_scale".into());
    }
    for l in &k.nest.loops {
        if l.dynamic {
            args.push(format!("int n_{}", l.var.name()));
        }
    }
    s.push_str(&args.join(", "));
    s.push_str(") {\n");
    let mut indent = 1usize;
    for l in &k.nest.loops {
        let pad = "  ".repeat(indent);
        let extent = if l.dynamic {
            format!("n_{}", l.var.name())
        } else {
            l.extent.to_string()
        };
        if l.unroll > 1 && l.unroll == l.extent && !l.dynamic {
            s.push_str(&format!("{pad}#pragma unroll\n"));
        } else if l.unroll > 1 {
            s.push_str(&format!("{pad}#pragma unroll {}\n", l.unroll));
        }
        s.push_str(&format!(
            "{pad}for (int {v} = 0; {v} < {extent}; ++{v}) {{\n",
            v = l.var.name()
        ));
        indent += 1;
    }
    let pad = "  ".repeat(indent);
    let accum = k.nest.precision.accum_c_type();
    let acc = match k.nest.accum_space {
        MemSpace::Private => format!("acc /*{accum} register*/"),
        MemSpace::Local => "acc_local[...]".to_string(),
        _ => "ofmap[...] /*global RMW*/".to_string(),
    };
    if k.nest.macs_per_iter > 0 {
        let in_src = k
            .nest
            .accesses
            .iter()
            .find(|a| a.buffer == "ifmap")
            .map(|a| match a.space {
                MemSpace::Channel => "read_channel_intel(ch_in)".to_string(),
                MemSpace::Local => "ifmap_local[...]".to_string(),
                _ => "ifmap[...]".to_string(),
            })
            .unwrap_or_else(|| "ifmap[...]".into());
        if k.nest.precision == Precision::Int8 {
            // int8 MACs widen into the integer accumulator.
            s.push_str(&format!("{pad}{acc} += (int){in_src} * (int)weights[...];\n"));
        } else {
            s.push_str(&format!("{pad}{acc} += {in_src} * weights[...];\n"));
        }
    } else {
        s.push_str(&format!("{pad}{acc} = reduce(ifmap[...]);\n"));
    }
    for _ in 0..k.nest.loops.len() {
        indent -= 1;
        s.push_str(&format!("{}}}\n", "  ".repeat(indent)));
    }
    if !k.nest.epilogue.is_empty() {
        let where_ = if k.nest.separate_epilogue {
            "/* SEPARATE loop (unfused): extra pass + temp array */"
        } else {
            "/* fused into reduction epilogue */"
        };
        s.push_str(&format!("  // epilogue: {:?} {}\n", k.nest.epilogue, where_));
        if !k.absorbed.is_empty() {
            s.push_str(&format!("  // absorbed graph nodes: {:?}\n", k.absorbed));
        }
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::schedule::Scheduler;
    use crate::texpr;

    fn kernel_for(node_idx: usize) -> Kernel {
        let g = models::lenet5();
        let n = &g.nodes[node_idx];
        let nest = texpr::lower(n, &g.nodes[n.inputs[0]].shape);
        Kernel {
            id: 0,
            name: nest.name.clone(),
            nest,
            applied: Default::default(),
            autorun: false,
            layers: vec![node_idx],
            absorbed: vec![],
            group: None,
            queue: 0,
        }
    }

    #[test]
    fn global_args_counted_once() {
        let k = kernel_for(1);
        assert_eq!(k.global_args(), 3); // ifmap, weights, ofmap
    }

    #[test]
    fn autorun_requires_no_global_access() {
        let mut k = kernel_for(2); // avgpool
        assert!(!k.autorun_eligible());
        let mut s = Scheduler::new(&mut k.nest);
        s.channelize("ifmap");
        s.channelize("ofmap");
        assert!(k.autorun_eligible());
    }

    #[test]
    fn pseudo_opencl_contains_pragmas() {
        let mut k = kernel_for(1);
        let mut s = Scheduler::new(&mut k.nest);
        s.unroll(crate::texpr::LoopVar::KW).unwrap();
        let prog = KernelProgram { name: "t".into(), kernels: vec![k], channels: vec![], queues: 1 };
        let src = prog.to_pseudo_opencl();
        assert!(src.contains("#pragma unroll"));
        assert!(src.contains("__kernel void"));
        assert!(src.contains("__global float*"));
    }

    #[test]
    fn channels_render_with_depth() {
        let prog = KernelProgram {
            name: "t".into(),
            kernels: vec![],
            channels: vec![Channel::f32("ch0", 0, 1, 256)],
            queues: 1,
        };
        let src = prog.to_pseudo_opencl();
        assert!(src.contains("depth(256)"));
        assert!(src.contains("channel float ch0"));
    }

    #[test]
    fn quantized_kernels_emit_their_element_types() {
        let mut k = kernel_for(1);
        let mut s = Scheduler::new(&mut k.nest);
        s.quantize(crate::texpr::Precision::Int8);
        s.cache_write().unwrap();
        let ch = Channel {
            name: "ch0".into(),
            from_kernel: 0,
            to_kernel: 1,
            depth: 64,
            elem: crate::texpr::Precision::Int8,
        };
        let prog = KernelProgram { name: "t".into(), kernels: vec![k], channels: vec![ch], queues: 1 };
        let src = prog.to_pseudo_opencl();
        assert!(src.contains("channel char ch0"), "{src}");
        assert!(src.contains("__global char* restrict"), "{src}");
        assert!(src.contains("dequant_scale"), "{src}");
        assert!(src.contains("(int)"), "int8 MACs must widen: {src}");
        assert!(!src.contains("__global float"), "{src}");
    }

    #[test]
    fn fp16_kernels_emit_half() {
        let mut k = kernel_for(1);
        let mut s = Scheduler::new(&mut k.nest);
        s.quantize(crate::texpr::Precision::F16);
        let prog = KernelProgram { name: "t".into(), kernels: vec![k], channels: vec![], queues: 1 };
        let src = prog.to_pseudo_opencl();
        assert!(src.contains("__global half* restrict"), "{src}");
    }
}
