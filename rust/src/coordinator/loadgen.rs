//! Trace-driven load generation: replay bursty/diurnal request traces
//! against an [`InferenceServer`](super::InferenceServer) fleet and report
//! per-class latency and shed rates.
//!
//! A [`LoadTrace`] is just a sorted list of [`TraceEvent`]s — "at `at_us`
//! microseconds into the run, submit one request of class `class`". Traces
//! come from the synthetic generators ([`LoadTrace::bursty`],
//! [`LoadTrace::diurnal`]) or from JSON (`{"events":[{"at_us":..,
//! "class":..}]}`), so a recorded production arrival process can be
//! replayed bit-for-bit. [`replay`] paces submissions to the trace
//! timestamps, classifies every outcome (answered, shed by admission
//! control, shed by backpressure, errored, dropped by a dead replica) and
//! merges the client-side view with the server's final
//! [`StatsSnapshot`](super::StatsSnapshot) into a [`ReplayReport`].

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::metrics::LatencyStats;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

use super::{InferenceServer, ServerError, StatsSnapshot};

/// One request arrival in a trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Offset from the start of the replay, in microseconds.
    pub at_us: u64,
    /// SLO class index of the request.
    pub class: usize,
}

/// An arrival process: sorted request timestamps with class labels.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LoadTrace {
    pub events: Vec<TraceEvent>,
}

impl LoadTrace {
    /// A bursty arrival process: `requests` arrivals in square-wave bursts
    /// — `burst` near-back-to-back requests at the start of every
    /// `period_us` window, idle in between. `mix` weights the class labels
    /// (round-robin over the expanded weight table, so the ratios are
    /// exact); `seed` jitters each arrival inside its burst
    /// deterministically.
    ///
    /// ```
    /// use tvm_fpga_flow::coordinator::loadgen::LoadTrace;
    ///
    /// let t = LoadTrace::bursty(100, 20, 10_000, &[1, 4], 42);
    /// assert_eq!(t.events.len(), 100);
    /// assert_eq!(t.class_counts(), vec![20, 80]);
    /// assert!(t.duration_us() >= 4 * 10_000);
    /// assert_eq!(t, LoadTrace::bursty(100, 20, 10_000, &[1, 4], 42)); // deterministic
    /// ```
    pub fn bursty(
        requests: usize,
        burst: usize,
        period_us: u64,
        mix: &[u32],
        seed: u64,
    ) -> LoadTrace {
        let burst = burst.max(1);
        let period_us = period_us.max(1);
        let mut rng = Rng::new(seed ^ 0xb0b5_7bad);
        // Arrivals land in the first quarter of their window.
        let jitter = (period_us / 4).max(1);
        let classes = expand_mix(mix);
        let mut events = Vec::with_capacity(requests);
        for i in 0..requests {
            let window = (i / burst) as u64;
            let at_us = window * period_us + rng.below(jitter);
            events.push(TraceEvent { at_us, class: classes[i % classes.len()] });
        }
        events.sort_by_key(|e| e.at_us);
        LoadTrace { events }
    }

    /// A diurnal arrival process: `requests` arrivals over `span_us`,
    /// density following `1 − cos` over `cycles` cycles (peaks mid-cycle,
    /// troughs at the boundaries — a day-scale load curve compressed into
    /// the span).
    pub fn diurnal(
        requests: usize,
        span_us: u64,
        cycles: u32,
        mix: &[u32],
        seed: u64,
    ) -> LoadTrace {
        let mut rng = Rng::new(seed ^ 0xd1a1_ca11);
        let cycles = cycles.max(1) as f64;
        let classes = expand_mix(mix);
        let mut events = Vec::with_capacity(requests);
        for i in 0..requests {
            // Rejection-sample the 1−cos density; ≤ 2 draws expected.
            let at_us = loop {
                let t = rng.f64();
                let density = 0.5 * (1.0 - (t * cycles * std::f64::consts::TAU).cos());
                if rng.f64() <= density {
                    break (t * span_us as f64) as u64;
                }
            };
            events.push(TraceEvent { at_us, class: classes[i % classes.len()] });
        }
        events.sort_by_key(|e| e.at_us);
        LoadTrace { events }
    }

    /// Wall-clock length of the trace (time of the last arrival).
    pub fn duration_us(&self) -> u64 {
        self.events.last().map(|e| e.at_us).unwrap_or(0)
    }

    /// Mean offered load over the trace duration, requests per second.
    pub fn offered_rps(&self) -> f64 {
        let d = self.duration_us();
        if d == 0 {
            0.0
        } else {
            self.events.len() as f64 * 1e6 / d as f64
        }
    }

    /// Per-class arrival counts (indexed by class, length = max class + 1).
    pub fn class_counts(&self) -> Vec<u64> {
        let n = self.events.iter().map(|e| e.class + 1).max().unwrap_or(0);
        let mut counts = vec![0u64; n];
        for e in &self.events {
            counts[e.class] += 1;
        }
        counts
    }

    /// Compress (divisor > 1) or stretch every timestamp, e.g. to replay a
    /// minutes-long recorded trace in test time.
    pub fn scaled(mut self, divisor: f64) -> LoadTrace {
        if divisor.is_finite() && divisor > 0.0 && divisor != 1.0 {
            for e in &mut self.events {
                e.at_us = (e.at_us as f64 / divisor) as u64;
            }
        }
        self
    }

    /// Serialize as the JSON trace format (round-trips through
    /// [`LoadTrace::parse`]).
    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                o.insert("at_us".to_string(), Json::Num(e.at_us as f64));
                o.insert("class".to_string(), Json::Num(e.class as f64));
                Json::Obj(o)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("events".to_string(), Json::Arr(events));
        Json::Obj(root)
    }

    /// Parse the JSON trace format: `{"events":[{"at_us":N,"class":N}]}`
    /// (`class` defaults to 0). Events are sorted by timestamp.
    pub fn parse(text: &str) -> crate::Result<LoadTrace> {
        let root = json::parse(text).map_err(|e| anyhow::anyhow!("bad trace JSON: {e}"))?;
        let events = root
            .get("events")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("trace JSON needs an \"events\" array"))?;
        let mut out = Vec::with_capacity(events.len());
        for (i, ev) in events.iter().enumerate() {
            let at_us = ev
                .get("at_us")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow::anyhow!("trace event {i}: missing \"at_us\""))?;
            let class = ev.get("class").and_then(Json::as_u64).unwrap_or(0) as usize;
            out.push(TraceEvent { at_us, class });
        }
        out.sort_by_key(|e| e.at_us);
        Ok(LoadTrace { events: out })
    }
}

/// Expand a weight table into an exact-ratio class cycle, e.g. `[1, 3]` →
/// `[0, 1, 1, 1]`. Zero/empty mixes fall back to a single class 0.
fn expand_mix(mix: &[u32]) -> Vec<usize> {
    let mut out = Vec::new();
    for (class, &w) in mix.iter().enumerate() {
        for _ in 0..w {
            out.push(class);
        }
    }
    if out.is_empty() {
        out.push(0);
    }
    out
}

/// Client-side per-class outcome accounting for one replay.
#[derive(Debug, Clone, Default)]
pub struct ClassReport {
    pub name: String,
    /// Deadline budget of the class, if any.
    pub deadline_us: Option<u64>,
    /// Requests the trace offered for this class.
    pub sent: u64,
    /// Accepted into the queue (answers arrived or were awaited).
    pub accepted: u64,
    /// Answered with a prediction.
    pub ok: u64,
    /// Shed under queue pressure (refused or evicted), `Overloaded`.
    pub shed_overload: u64,
    /// Shed before queueing, `DeadlineUnmeetable`.
    pub shed_deadline: u64,
    /// Answered with some other server error.
    pub errored: u64,
    /// Accepted but never answered (replica died mid-batch).
    pub dropped: u64,
    /// Client-observed submit→response percentiles over answered requests.
    pub p50_us: Option<u64>,
    pub p99_us: Option<u64>,
}

impl ClassReport {
    /// Requests shed for any reason.
    pub fn shed_total(&self) -> u64 {
        self.shed_overload + self.shed_deadline
    }

    /// Shed fraction of everything sent (0.0 when nothing was sent).
    pub fn shed_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.shed_total() as f64 / self.sent as f64
        }
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Json::Str(self.name.clone()));
        match self.deadline_us {
            Some(d) => o.insert("deadline_us".into(), Json::Num(d as f64)),
            None => o.insert("deadline_us".into(), Json::Null),
        };
        o.insert("sent".into(), Json::Num(self.sent as f64));
        o.insert("accepted".into(), Json::Num(self.accepted as f64));
        o.insert("ok".into(), Json::Num(self.ok as f64));
        o.insert("shed_overload".into(), Json::Num(self.shed_overload as f64));
        o.insert("shed_deadline".into(), Json::Num(self.shed_deadline as f64));
        o.insert("errored".into(), Json::Num(self.errored as f64));
        o.insert("dropped".into(), Json::Num(self.dropped as f64));
        o.insert("shed_rate".into(), Json::Num(self.shed_rate()));
        match self.p50_us {
            Some(p) => o.insert("p50_us".into(), Json::Num(p as f64)),
            None => o.insert("p50_us".into(), Json::Null),
        };
        match self.p99_us {
            Some(p) => o.insert("p99_us".into(), Json::Num(p as f64)),
            None => o.insert("p99_us".into(), Json::Null),
        };
        Json::Obj(o)
    }
}

/// Everything one [`replay`] produced: the client-side per-class view, the
/// replay timing, and the server's final snapshot.
#[derive(Debug, Clone)]
pub struct ReplayReport {
    /// One entry per class, priority order.
    pub classes: Vec<ClassReport>,
    /// Wall time of the whole replay (submit start → last answer).
    pub wall_us: u64,
    /// Mean load the trace offered.
    pub offered_rps: f64,
    /// Answered requests per second of replay wall time.
    pub achieved_rps: f64,
    /// The server's own final statistics.
    pub snapshot: StatsSnapshot,
}

impl ReplayReport {
    /// Requests shed for any reason, across classes.
    pub fn total_shed(&self) -> u64 {
        self.classes.iter().map(ClassReport::shed_total).sum()
    }

    /// Fraction of total shedding absorbed by class `i` (0.0 when nothing
    /// was shed).
    pub fn shed_share(&self, i: usize) -> f64 {
        let total = self.total_shed();
        if total == 0 {
            0.0
        } else {
            self.classes.get(i).map(ClassReport::shed_total).unwrap_or(0) as f64 / total as f64
        }
    }

    /// The per-class report as JSON (the `loadgen --json` payload and the
    /// CI shape check).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert(
            "classes".into(),
            Json::Arr(self.classes.iter().map(ClassReport::to_json).collect()),
        );
        o.insert("wall_us".into(), Json::Num(self.wall_us as f64));
        o.insert("offered_rps".into(), Json::Num(self.offered_rps));
        o.insert("achieved_rps".into(), Json::Num(self.achieved_rps));
        o.insert("total_shed".into(), Json::Num(self.total_shed() as f64));
        o.insert("submitted".into(), Json::Num(self.snapshot.submitted as f64));
        o.insert("completed".into(), Json::Num(self.snapshot.completed as f64));
        o.insert("queue_samples".into(), Json::Num(self.snapshot.queue_samples as f64));
        Json::Obj(o)
    }

    /// Human-readable per-class table.
    pub fn render(&self) -> String {
        let mut s = format!(
            "replayed {:.0} rps offered -> {:.0} rps answered over {:.1} ms\n",
            self.offered_rps,
            self.achieved_rps,
            self.wall_us as f64 / 1e3
        );
        for (i, c) in self.classes.iter().enumerate() {
            let deadline = match c.deadline_us {
                Some(d) => format!("{d}us"),
                None => "best-effort".into(),
            };
            let p99 = match c.p99_us {
                Some(p) => format!("{p}us"),
                None => "-".into(),
            };
            s.push_str(&format!(
                "  class {i} {:<12} [{deadline}] sent {:>6}  ok {:>6}  shed {:>5} ({:>5.1}%)  p99 {p99}\n",
                c.name,
                c.sent,
                c.ok,
                c.shed_total(),
                c.shed_rate() * 100.0,
            ));
        }
        s
    }

    /// Export the replay outcome as `flow_loadgen_*` gauges (per-class
    /// shed/latency plus totals), alongside the snapshot's own
    /// `flow_serve_*` metrics.
    pub fn export_metrics(&self, reg: &crate::obs::Registry) {
        self.snapshot.export_metrics(reg);
        reg.set_gauge("flow_loadgen_offered_rps", "mean offered load", self.offered_rps);
        reg.set_gauge("flow_loadgen_achieved_rps", "answered requests per second", self.achieved_rps);
        reg.set_gauge("flow_loadgen_total_shed", "requests shed across classes", self.total_shed() as f64);
        for (i, c) in self.classes.iter().enumerate() {
            reg.set_gauge(
                &format!("flow_loadgen_class_{i}_sent"),
                &format!("requests offered for class {}", c.name),
                c.sent as f64,
            );
            reg.set_gauge(
                &format!("flow_loadgen_class_{i}_shed"),
                &format!("requests shed for class {}", c.name),
                c.shed_total() as f64,
            );
            if let Some(p) = c.p99_us {
                reg.set_gauge(
                    &format!("flow_loadgen_class_{i}_p99_us"),
                    &format!("client-observed p99 for class {}", c.name),
                    p as f64,
                );
            }
        }
    }
}

/// Replay a trace against a running server: pace submissions to the trace
/// timestamps (cycling through `frames` for payloads), then await every
/// accepted response. The server is left running — callers own shutdown
/// (and typically fold `server.shutdown()` into
/// [`ReplayReport::snapshot`]).
pub fn replay(server: &InferenceServer, trace: &LoadTrace, frames: &[Vec<f32>]) -> ReplayReport {
    assert!(!frames.is_empty(), "replay needs at least one payload frame");
    let n_classes = trace.events.iter().map(|e| e.class + 1).max().unwrap_or(1);
    let mut classes: Vec<ClassReport> = (0..n_classes)
        .map(|i| ClassReport { name: format!("class{i}"), ..ClassReport::default() })
        .collect();
    let mut pending: Vec<(usize, std::sync::mpsc::Receiver<crate::Result<u32>>)> = Vec::new();
    let mut latencies: Vec<LatencyStats> = vec![LatencyStats::default(); n_classes];
    let mut submit_times: Vec<Instant> = Vec::new();

    let t0 = Instant::now();
    for ev in &trace.events {
        let due = t0 + Duration::from_micros(ev.at_us);
        let now = Instant::now();
        if due > now {
            std::thread::sleep(due - now);
        }
        let c = &mut classes[ev.class];
        c.sent += 1;
        let frame = frames[(c.sent as usize + ev.class) % frames.len()].clone();
        match server.infer_class_async(frame, ev.class) {
            Ok(rx) => {
                c.accepted += 1;
                submit_times.push(Instant::now());
                pending.push((ev.class, rx));
            }
            Err(e) => match e.downcast_ref::<ServerError>() {
                Some(ServerError::DeadlineUnmeetable { .. }) => c.shed_deadline += 1,
                Some(ServerError::Overloaded { .. }) => c.shed_overload += 1,
                _ => c.errored += 1,
            },
        }
    }

    for ((class, rx), submitted) in pending.into_iter().zip(submit_times) {
        match rx.recv() {
            Ok(Ok(_)) => {
                classes[class].ok += 1;
                latencies[class].record(submitted.elapsed().as_micros() as u64);
            }
            Ok(Err(e)) => match e.downcast_ref::<ServerError>() {
                // An accepted request answered Overloaded was evicted by a
                // higher-priority arrival — it still sheds.
                Some(ServerError::Overloaded { .. }) => classes[class].shed_overload += 1,
                _ => classes[class].errored += 1,
            },
            // The response sender died with its replica worker.
            Err(_) => classes[class].dropped += 1,
        }
    }
    let wall_us = t0.elapsed().as_micros().max(1) as u64;

    let snapshot = server.stats();
    for (i, c) in classes.iter_mut().enumerate() {
        c.p50_us = latencies[i].percentile(50.0);
        c.p99_us = latencies[i].percentile(99.0);
        if let Some(sc) = snapshot.classes.get(i) {
            c.name = sc.name.clone();
            c.deadline_us = sc.deadline_us;
        }
    }
    let ok: u64 = classes.iter().map(|c| c.ok).sum();
    ReplayReport {
        classes,
        wall_us,
        offered_rps: trace.offered_rps(),
        achieved_rps: ok as f64 * 1e6 / wall_us as f64,
        snapshot,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bursty_trace_is_deterministic_and_mixed_exactly() {
        let a = LoadTrace::bursty(120, 30, 5_000, &[20, 20, 80], 7);
        let b = LoadTrace::bursty(120, 30, 5_000, &[20, 20, 80], 7);
        assert_eq!(a, b);
        assert_eq!(a.events.len(), 120);
        assert_eq!(a.class_counts(), vec![20, 20, 80]);
        // Square wave: 4 windows of 30, all arrivals inside the first
        // quarter of their 5 ms window.
        for e in &a.events {
            assert!(e.at_us % 5_000 < 1_250, "{e:?}");
        }
        assert!(a.offered_rps() > 0.0);
        // A different seed moves the jitter but not the shape.
        let c = LoadTrace::bursty(120, 30, 5_000, &[20, 20, 80], 8);
        assert_ne!(a, c);
        assert_eq!(c.class_counts(), vec![20, 20, 80]);
    }

    #[test]
    fn diurnal_trace_peaks_mid_cycle() {
        let t = LoadTrace::diurnal(2_000, 1_000_000, 2, &[1], 42);
        assert_eq!(t.events.len(), 2_000);
        // Two cycles over 1 s: peaks near 250 ms and 750 ms, troughs near
        // 0/500 ms/1 s. Compare the density around a peak vs a trough.
        let near = |center: u64, width: u64| {
            t.events
                .iter()
                .filter(|e| e.at_us.abs_diff(center) < width)
                .count()
        };
        let peak = near(250_000, 50_000);
        let trough = near(500_000, 50_000);
        assert!(peak > 3 * trough.max(1), "peak {peak} vs trough {trough}");
    }

    #[test]
    fn trace_json_round_trips() {
        let t = LoadTrace::bursty(40, 10, 1_000, &[1, 3], 9);
        let text = t.to_json().to_string();
        let back = LoadTrace::parse(&text).unwrap();
        assert_eq!(t, back);
        // Class defaults to 0; garbage is a clean error.
        let one = LoadTrace::parse(r#"{"events":[{"at_us":5}]}"#).unwrap();
        assert_eq!(one.events, vec![TraceEvent { at_us: 5, class: 0 }]);
        assert!(LoadTrace::parse("[]").is_err());
        assert!(LoadTrace::parse(r#"{"events":[{"class":1}]}"#).is_err());
        // Parsing sorts unsorted events.
        let unsorted =
            LoadTrace::parse(r#"{"events":[{"at_us":9},{"at_us":2}]}"#).unwrap();
        assert_eq!(unsorted.events[0].at_us, 2);
    }

    #[test]
    fn scaled_compresses_timestamps() {
        let t = LoadTrace::bursty(20, 5, 100_000, &[1], 3);
        let fast = t.clone().scaled(100.0);
        assert_eq!(fast.events.len(), t.events.len());
        assert!(fast.duration_us() <= t.duration_us() / 99);
        // Degenerate divisors are identity.
        assert_eq!(t.clone().scaled(0.0), t);
    }

    #[test]
    fn expand_mix_is_exact_and_survives_zeros() {
        assert_eq!(expand_mix(&[1, 3]), vec![0, 1, 1, 1]);
        assert_eq!(expand_mix(&[0, 2]), vec![1, 1]);
        assert_eq!(expand_mix(&[]), vec![0]);
        assert_eq!(expand_mix(&[0, 0]), vec![0]);
    }
}
