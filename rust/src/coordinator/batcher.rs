//! Dynamic batch queue: coalesces single submissions into device-native
//! batches, flushing on size or on a latency deadline (§IV-F's
//! amortize-the-dispatch insight applied to serving).
//!
//! The queue is *bounded*: a full queue rejects the push instead of
//! buffering unboundedly, which is how the server surfaces
//! [`super::ServerError::Overloaded`] backpressure to callers.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// The queue is at capacity — shed load or retry later.
    Full(T),
    /// [`BatchQueue::close`] has been called; no new work is accepted.
    Closed(T),
}

struct Inner<T> {
    queue: VecDeque<(T, Instant)>,
    closed: bool,
}

/// A bounded, deadline-flushing batch queue.
///
/// `pop_batch` blocks until at least one item is queued, then keeps
/// collecting until either `max_batch` items are available or the *oldest*
/// queued item has waited `max_delay` — so the first frame of a batch
/// bounds the extra latency batching can add.
///
/// ```
/// use std::time::Duration;
/// use tvm_fpga_flow::coordinator::BatchQueue;
///
/// let q: BatchQueue<u32> = BatchQueue::new(64, 8, Duration::from_micros(200));
/// for i in 0..3 {
///     q.push(i).unwrap();
/// }
/// // Fewer than max_batch items queued: the deadline flushes a partial batch.
/// assert_eq!(q.pop_batch(), Some(vec![0, 1, 2]));
/// q.close();
/// assert_eq!(q.pop_batch(), None); // closed and drained
/// ```
pub struct BatchQueue<T> {
    inner: Mutex<Inner<T>>,
    nonempty: Condvar,
    capacity: usize,
    max_batch: usize,
    max_delay: Duration,
}

impl<T> BatchQueue<T> {
    /// A queue holding at most `capacity` pending items, batching up to
    /// `max_batch` of them, holding a partial batch at most `max_delay`.
    pub fn new(capacity: usize, max_batch: usize, max_delay: Duration) -> BatchQueue<T> {
        BatchQueue {
            inner: Mutex::new(Inner { queue: VecDeque::new(), closed: false }),
            nonempty: Condvar::new(),
            capacity: capacity.max(1),
            max_batch: max_batch.max(1),
            max_delay,
        }
    }

    /// Enqueue one item. Fails immediately (returning the item) when the
    /// queue is full or closed — never blocks the submitting thread.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        if inner.queue.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        inner.queue.push_back((item, Instant::now()));
        drop(inner);
        self.nonempty.notify_one();
        Ok(())
    }

    /// Block until a batch is ready; `None` once the queue is closed *and*
    /// drained. After `close()`, queued items keep coming out (possibly as
    /// partial batches, with no deadline wait) until the queue is empty —
    /// shutdown never drops accepted work.
    pub fn pop_batch(&self) -> Option<Vec<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(&(_, enqueued)) = inner.queue.front() {
                let deadline = enqueued + self.max_delay;
                // Fill up to max_batch within the oldest item's deadline.
                while inner.queue.len() < self.max_batch && !inner.closed {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) =
                        self.nonempty.wait_timeout(inner, deadline - now).unwrap();
                    inner = guard;
                    if timeout.timed_out() {
                        break;
                    }
                }
                let k = inner.queue.len().min(self.max_batch);
                if crate::obs::enabled() {
                    let reason = if k == self.max_batch {
                        "flow_serve_flush_full_total"
                    } else if inner.closed {
                        "flow_serve_flush_close_total"
                    } else {
                        "flow_serve_flush_deadline_total"
                    };
                    crate::obs::global_metrics()
                        .counter(reason, "batch flushes by trigger (size/deadline/close)")
                        .inc();
                }
                return Some(inner.queue.drain(..k).map(|(item, _)| item).collect());
            }
            if inner.closed {
                return None;
            }
            inner = self.nonempty.wait(inner).unwrap();
        }
    }

    /// Stop accepting work and wake every blocked `pop_batch`. Pending
    /// items remain poppable; new pushes fail with [`PushError::Closed`].
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.nonempty.notify_all();
    }

    /// Items currently queued (racy by nature; metrics only).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The bound enforced by [`BatchQueue::push`].
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_flushes_without_waiting() {
        let q: BatchQueue<u32> = BatchQueue::new(64, 4, Duration::from_secs(10));
        for i in 0..9 {
            q.push(i).unwrap();
        }
        // A 10 s deadline would hang the test if size-triggered flushing
        // didn't short-circuit it.
        assert_eq!(q.pop_batch(), Some(vec![0, 1, 2, 3]));
        assert_eq!(q.pop_batch(), Some(vec![4, 5, 6, 7]));
        q.close();
        assert_eq!(q.pop_batch(), Some(vec![8]));
        assert_eq!(q.pop_batch(), None);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let q: BatchQueue<u32> = BatchQueue::new(64, 8, Duration::from_millis(20));
        let t0 = Instant::now();
        q.push(7).unwrap();
        let batch = q.pop_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch, vec![7]);
        // It must have waited for the deadline (nothing else arrived), but
        // not unboundedly.
        assert!(waited >= Duration::from_millis(15), "{waited:?}");
        assert!(waited < Duration::from_secs(5), "{waited:?}");
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let q: BatchQueue<u32> = BatchQueue::new(2, 8, Duration::from_millis(1));
        q.push(0).unwrap();
        q.push(1).unwrap();
        assert_eq!(q.push(2), Err(PushError::Full(2)));
        assert_eq!(q.len(), 2);
        // Draining makes room again.
        assert_eq!(q.pop_batch(), Some(vec![0, 1]));
        q.push(3).unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn close_rejects_new_work_but_drains_old() {
        let q: BatchQueue<u32> = BatchQueue::new(8, 8, Duration::from_secs(10));
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.push(2), Err(PushError::Closed(2)));
        // No deadline wait after close: the partial batch flushes at once.
        let t0 = Instant::now();
        assert_eq!(q.pop_batch(), Some(vec![1]));
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(q.pop_batch(), None);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q: Arc<BatchQueue<u32>> = Arc::new(BatchQueue::new(8, 8, Duration::from_millis(1)));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_batch());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn late_arrivals_join_the_open_batch() {
        let q: Arc<BatchQueue<u32>> = Arc::new(BatchQueue::new(64, 4, Duration::from_millis(150)));
        q.push(0).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            for i in 1..4 {
                q2.push(i).unwrap();
            }
        });
        // The batch fills to max_batch well before the 150 ms deadline.
        let t0 = Instant::now();
        let batch = q.pop_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_millis(140), "{:?}", t0.elapsed());
    }
}
