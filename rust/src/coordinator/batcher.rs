//! Dynamic batch queue: coalesces single submissions into device-native
//! batches, flushing on size or on a latency deadline (§IV-F's
//! amortize-the-dispatch insight applied to serving).
//!
//! The queue is *bounded*: a full queue rejects the push instead of
//! buffering unboundedly, which is how the server surfaces
//! [`super::ServerError::Overloaded`] backpressure to callers.
//!
//! The queue is also *priority-aware*: it holds one FIFO lane per SLO
//! class (class 0 highest). `pop_batch` drains high-priority lanes first,
//! and when the queue is full a higher-priority push can evict the
//! youngest item of the lowest-priority class present
//! ([`BatchQueue::push_class`] returns the victim so the server can
//! answer it with `Overloaded`) — shed-lowest-first under pressure.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a push was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum PushError<T> {
    /// The queue is at capacity — shed load or retry later.
    Full(T),
    /// [`BatchQueue::close`] has been called; no new work is accepted.
    Closed(T),
}

/// What actually woke `pop_batch` into flushing a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// The batch filled to `max_batch`.
    Full,
    /// The oldest queued item hit its `max_delay` deadline.
    Deadline,
    /// [`BatchQueue::close`] flushed a partial batch during drain.
    Close,
}

/// Cumulative flush counts by [`FlushReason`], from
/// [`BatchQueue::flush_counts`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlushCounts {
    pub full: u64,
    pub deadline: u64,
    pub close: u64,
}

struct Inner<T> {
    /// One FIFO lane per class; index = priority (0 drains first).
    lanes: Vec<VecDeque<(T, Instant)>>,
    /// Total queued items across all lanes.
    len: usize,
    closed: bool,
}

impl<T> Inner<T> {
    /// Enqueue time of the oldest item across all lanes — the deadline
    /// anchor. Priority changes who *drains* first, not whose latency
    /// budget arms the flush timer.
    fn oldest(&self) -> Option<Instant> {
        self.lanes.iter().filter_map(|l| l.front().map(|&(_, t)| t)).min()
    }
}

/// A bounded, deadline-flushing, priority-aware batch queue.
///
/// `pop_batch` blocks until at least one item is queued, then keeps
/// collecting until either `max_batch` items are available or the *oldest*
/// queued item has waited `max_delay` — so the first frame of a batch
/// bounds the extra latency batching can add.
///
/// ```
/// use std::time::Duration;
/// use tvm_fpga_flow::coordinator::BatchQueue;
///
/// let q: BatchQueue<u32> = BatchQueue::new(64, 8, Duration::from_micros(200));
/// for i in 0..3 {
///     q.push(i).unwrap();
/// }
/// // Fewer than max_batch items queued: the deadline flushes a partial batch.
/// assert_eq!(q.pop_batch(), Some(vec![0, 1, 2]));
/// q.close();
/// assert_eq!(q.pop_batch(), None); // closed and drained
/// ```
pub struct BatchQueue<T> {
    inner: Mutex<Inner<T>>,
    nonempty: Condvar,
    capacity: usize,
    max_batch: usize,
    max_delay: Duration,
    flush_full: AtomicU64,
    flush_deadline: AtomicU64,
    flush_close: AtomicU64,
}

impl<T> BatchQueue<T> {
    /// A single-lane queue holding at most `capacity` pending items,
    /// batching up to `max_batch` of them, holding a partial batch at most
    /// `max_delay`.
    pub fn new(capacity: usize, max_batch: usize, max_delay: Duration) -> BatchQueue<T> {
        BatchQueue::with_classes(capacity, max_batch, max_delay, 1)
    }

    /// A queue with one priority lane per class (class 0 drains first).
    pub fn with_classes(
        capacity: usize,
        max_batch: usize,
        max_delay: Duration,
        num_classes: usize,
    ) -> BatchQueue<T> {
        let num_classes = num_classes.max(1);
        BatchQueue {
            inner: Mutex::new(Inner {
                lanes: (0..num_classes).map(|_| VecDeque::new()).collect(),
                len: 0,
                closed: false,
            }),
            nonempty: Condvar::new(),
            capacity: capacity.max(1),
            max_batch: max_batch.max(1),
            max_delay,
            flush_full: AtomicU64::new(0),
            flush_deadline: AtomicU64::new(0),
            flush_close: AtomicU64::new(0),
        }
    }

    /// Enqueue one item into the highest-priority lane. Fails immediately
    /// (returning the item) when the queue is full or closed — never
    /// blocks the submitting thread.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        match self.push_class(item, 0) {
            Ok(victim) => {
                // On the single-lane queues `new` builds there is no
                // strictly-lower lane, so eviction can never occur here.
                // Multi-lane queues must use `push_class`, which hands the
                // victim back instead of dropping it.
                debug_assert!(victim.is_none(), "plain push must not evict");
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    /// Enqueue one item into the lane for `class` (clamped to the lane
    /// count). When the queue is full, the youngest item of the
    /// *lowest-priority* non-empty lane strictly below `class` is evicted
    /// to make room and returned as `Ok(Some(victim))` — the caller owns
    /// answering it (shed-lowest-first). With no lower-priority item to
    /// shed, the push itself fails with [`PushError::Full`].
    pub fn push_class(&self, item: T, class: usize) -> Result<Option<T>, PushError<T>> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return Err(PushError::Closed(item));
        }
        let class = class.min(inner.lanes.len() - 1);
        let mut victim = None;
        if inner.len >= self.capacity {
            // Evict from the back (youngest) of the lowest-priority
            // non-empty lane below `class`; the oldest lower-priority
            // items keep their place so their deadline anchor is honest.
            match (class + 1..inner.lanes.len()).rev().find(|&i| !inner.lanes[i].is_empty()) {
                Some(i) => {
                    victim = inner.lanes[i].pop_back().map(|(v, _)| v);
                    inner.len -= 1;
                }
                None => return Err(PushError::Full(item)),
            }
        }
        inner.lanes[class].push_back((item, Instant::now()));
        inner.len += 1;
        drop(inner);
        self.nonempty.notify_one();
        Ok(victim)
    }

    /// Block until a batch is ready; `None` once the queue is closed *and*
    /// drained. After `close()`, queued items keep coming out (possibly as
    /// partial batches, with no deadline wait) until the queue is empty —
    /// shutdown never drops accepted work. Batches drain lane 0 first,
    /// then lane 1, … — within a batch, higher-priority items always
    /// precede lower-priority ones.
    pub fn pop_batch(&self) -> Option<Vec<T>> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(enqueued) = inner.oldest() {
                let deadline = enqueued + self.max_delay;
                // Fill up to max_batch within the oldest item's deadline,
                // recording *why* the fill loop stopped: racing wakeups
                // (a close() landing after the deadline already expired, a
                // fill-to-max during the final timeout) must be attributed
                // to the condition that actually released the batch, which
                // is only knowable at the wake site.
                let reason = loop {
                    if inner.len >= self.max_batch {
                        break FlushReason::Full;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        // Checked before `closed`: once the deadline has
                        // expired the batch was already due — a close()
                        // racing in afterwards didn't release it.
                        break FlushReason::Deadline;
                    }
                    if inner.closed {
                        break FlushReason::Close;
                    }
                    let (guard, timeout) =
                        self.nonempty.wait_timeout(inner, deadline - now).unwrap();
                    inner = guard;
                    if timeout.timed_out() {
                        // A push can slip in between the timeout firing
                        // and this thread reacquiring the lock; if it
                        // filled the batch, the flush is a size flush.
                        if inner.len >= self.max_batch {
                            break FlushReason::Full;
                        }
                        break FlushReason::Deadline;
                    }
                };
                match reason {
                    FlushReason::Full => self.flush_full.fetch_add(1, Ordering::Relaxed),
                    FlushReason::Deadline => self.flush_deadline.fetch_add(1, Ordering::Relaxed),
                    FlushReason::Close => self.flush_close.fetch_add(1, Ordering::Relaxed),
                };
                if crate::obs::enabled() {
                    let name = match reason {
                        FlushReason::Full => "flow_serve_flush_full_total",
                        FlushReason::Deadline => "flow_serve_flush_deadline_total",
                        FlushReason::Close => "flow_serve_flush_close_total",
                    };
                    crate::obs::global_metrics()
                        .counter(name, "batch flushes by trigger (size/deadline/close)")
                        .inc();
                }
                let k = inner.len.min(self.max_batch);
                let mut out = Vec::with_capacity(k);
                'fill: for lane in inner.lanes.iter_mut() {
                    while out.len() < k {
                        match lane.pop_front() {
                            Some((item, _)) => out.push(item),
                            None => continue 'fill,
                        }
                    }
                    break;
                }
                inner.len -= k;
                return Some(out);
            }
            if inner.closed {
                return None;
            }
            inner = self.nonempty.wait(inner).unwrap();
        }
    }

    /// Stop accepting work and wake every blocked `pop_batch`. Pending
    /// items remain poppable; new pushes fail with [`PushError::Closed`].
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.nonempty.notify_all();
    }

    /// Items currently queued (racy by nature; metrics only).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The bound enforced by [`BatchQueue::push`].
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of priority lanes.
    pub fn num_classes(&self) -> usize {
        self.inner.lock().unwrap().lanes.len()
    }

    /// Cumulative batch-flush counts by wake cause.
    pub fn flush_counts(&self) -> FlushCounts {
        FlushCounts {
            full: self.flush_full.load(Ordering::Relaxed),
            deadline: self.flush_deadline.load(Ordering::Relaxed),
            close: self.flush_close.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn full_batch_flushes_without_waiting() {
        let q: BatchQueue<u32> = BatchQueue::new(64, 4, Duration::from_secs(10));
        for i in 0..9 {
            q.push(i).unwrap();
        }
        // A 10 s deadline would hang the test if size-triggered flushing
        // didn't short-circuit it.
        assert_eq!(q.pop_batch(), Some(vec![0, 1, 2, 3]));
        assert_eq!(q.pop_batch(), Some(vec![4, 5, 6, 7]));
        q.close();
        assert_eq!(q.pop_batch(), Some(vec![8]));
        assert_eq!(q.pop_batch(), None);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let q: BatchQueue<u32> = BatchQueue::new(64, 8, Duration::from_millis(20));
        let t0 = Instant::now();
        q.push(7).unwrap();
        let batch = q.pop_batch().unwrap();
        let waited = t0.elapsed();
        assert_eq!(batch, vec![7]);
        // It must have waited for the deadline (nothing else arrived), but
        // not unboundedly.
        assert!(waited >= Duration::from_millis(15), "{waited:?}");
        assert!(waited < Duration::from_secs(5), "{waited:?}");
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let q: BatchQueue<u32> = BatchQueue::new(2, 8, Duration::from_millis(1));
        q.push(0).unwrap();
        q.push(1).unwrap();
        assert_eq!(q.push(2), Err(PushError::Full(2)));
        assert_eq!(q.len(), 2);
        // Draining makes room again.
        assert_eq!(q.pop_batch(), Some(vec![0, 1]));
        q.push(3).unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn close_rejects_new_work_but_drains_old() {
        let q: BatchQueue<u32> = BatchQueue::new(8, 8, Duration::from_secs(10));
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.push(2), Err(PushError::Closed(2)));
        // No deadline wait after close: the partial batch flushes at once.
        let t0 = Instant::now();
        assert_eq!(q.pop_batch(), Some(vec![1]));
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(q.pop_batch(), None);
    }

    #[test]
    fn close_wakes_blocked_consumer() {
        let q: Arc<BatchQueue<u32>> = Arc::new(BatchQueue::new(8, 8, Duration::from_millis(1)));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_batch());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn late_arrivals_join_the_open_batch() {
        let q: Arc<BatchQueue<u32>> = Arc::new(BatchQueue::new(64, 4, Duration::from_millis(150)));
        q.push(0).unwrap();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            for i in 1..4 {
                q2.push(i).unwrap();
            }
        });
        // The batch fills to max_batch well before the 150 ms deadline.
        let t0 = Instant::now();
        let batch = q.pop_batch().unwrap();
        h.join().unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_millis(140), "{:?}", t0.elapsed());
    }

    // ---- flush-reason attribution (the wake-cause bugfix) ----

    #[test]
    fn flush_counters_attribute_full_deadline_and_close() {
        let q: BatchQueue<u32> = BatchQueue::new(64, 2, Duration::from_millis(10));
        q.push(0).unwrap();
        q.push(1).unwrap();
        assert_eq!(q.pop_batch(), Some(vec![0, 1]));
        assert_eq!(q.flush_counts(), FlushCounts { full: 1, deadline: 0, close: 0 });

        q.push(2).unwrap();
        assert_eq!(q.pop_batch(), Some(vec![2]));
        assert_eq!(q.flush_counts(), FlushCounts { full: 1, deadline: 1, close: 0 });

        q.push(3).unwrap();
        q.close();
        assert_eq!(q.pop_batch(), Some(vec![3]));
        assert_eq!(q.flush_counts(), FlushCounts { full: 1, deadline: 1, close: 1 });
    }

    #[test]
    fn close_after_deadline_expiry_counts_deadline_not_close() {
        // With a zero max_delay the deadline has expired the moment the
        // item lands; a close() racing in afterwards did not release the
        // batch and must not claim the flush.
        let q: BatchQueue<u32> = BatchQueue::new(8, 8, Duration::ZERO);
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.pop_batch(), Some(vec![1]));
        let c = q.flush_counts();
        assert_eq!((c.deadline, c.close), (1, 0), "{c:?}");
    }

    #[test]
    fn fill_to_max_during_final_wait_counts_full_not_deadline() {
        // An expired deadline with a full batch already queued is a size
        // flush: the batch never waited on the timer.
        let q: BatchQueue<u32> = BatchQueue::new(8, 2, Duration::ZERO);
        q.push(0).unwrap();
        q.push(1).unwrap();
        assert_eq!(q.pop_batch(), Some(vec![0, 1]));
        let c = q.flush_counts();
        assert_eq!((c.full, c.deadline), (1, 0), "{c:?}");
    }

    // ---- priority lanes ----

    #[test]
    fn batches_drain_high_priority_lanes_first() {
        let q: BatchQueue<u32> = BatchQueue::with_classes(64, 4, Duration::from_millis(5), 3);
        q.push_class(20, 2).unwrap();
        q.push_class(10, 1).unwrap();
        q.push_class(0, 0).unwrap();
        q.push_class(11, 1).unwrap();
        // Lane order beats arrival order; FIFO within a lane.
        assert_eq!(q.pop_batch(), Some(vec![0, 10, 11, 20]));
    }

    #[test]
    fn full_queue_evicts_youngest_of_lowest_class() {
        let q: BatchQueue<u32> = BatchQueue::with_classes(3, 8, Duration::from_millis(5), 3);
        q.push_class(20, 2).unwrap();
        q.push_class(21, 2).unwrap();
        q.push_class(10, 1).unwrap();
        // Full. A class-0 push evicts the *youngest* class-2 item.
        assert_eq!(q.push_class(0, 0), Ok(Some(21)));
        assert_eq!(q.len(), 3);
        // Another class-0 push evicts the remaining class-2 item, then the
        // next one evicts the class-1 item (lowest present below class 0).
        assert_eq!(q.push_class(1, 0), Ok(Some(20)));
        assert_eq!(q.push_class(2, 0), Ok(Some(10)));
        // Queue is now all class 0: nothing lower to shed.
        assert_eq!(q.push_class(3, 0), Err(PushError::Full(3)));
        assert_eq!(q.pop_batch(), Some(vec![0, 1, 2]));
    }

    #[test]
    fn equal_or_lower_class_cannot_evict() {
        let q: BatchQueue<u32> = BatchQueue::with_classes(2, 8, Duration::from_secs(10), 3);
        q.push_class(10, 1).unwrap();
        q.push_class(11, 1).unwrap();
        // Same class: no eviction (only strictly lower lanes are victims).
        assert_eq!(q.push_class(12, 1), Err(PushError::Full(12)));
        // Lower class: definitely not.
        assert_eq!(q.push_class(20, 2), Err(PushError::Full(20)));
        // Higher class: evicts.
        assert_eq!(q.push_class(0, 0), Ok(Some(11)));
    }

    #[test]
    fn out_of_range_class_clamps_to_lowest_lane() {
        let q: BatchQueue<u32> = BatchQueue::with_classes(4, 8, Duration::from_millis(5), 2);
        q.push_class(9, 99).unwrap();
        q.push_class(0, 0).unwrap();
        assert_eq!(q.pop_batch(), Some(vec![0, 9]));
    }
}
