//! SLO priority classes for the serving coordinator.
//!
//! A [`SloClass`] is a named service tier with an optional total-latency
//! deadline budget. A server carries an ordered class table
//! ([`super::ServerConfig::classes`]); a request names its class by index
//! and the *index is the priority* — class 0 is the most important tier,
//! the last class the most sheddable. That convention drives two
//! admission-control behaviours:
//!
//! * **shed-before-queue** — a request whose deadline cannot be met given
//!   the current queue-latency percentiles is rejected at submission with
//!   [`super::ServerError::DeadlineUnmeetable`], before it ever occupies a
//!   queue slot (so rejected requests record zero queue latency);
//! * **shed-lowest-first** — when the bounded queue is full, an arriving
//!   higher-priority request evicts the most recently queued item of the
//!   lowest-priority class present instead of being refused itself
//!   (the evicted request is answered with
//!   [`super::ServerError::Overloaded`]).

use std::time::Duration;

/// One service tier: a name plus an optional submit→response deadline.
///
/// ```
/// use std::time::Duration;
/// use tvm_fpga_flow::coordinator::SloClass;
///
/// let gold = SloClass::new("gold", Duration::from_millis(20));
/// assert_eq!(gold.deadline_us(), Some(20_000));
/// let bulk = SloClass::best_effort("bulk");
/// assert_eq!(bulk.deadline_us(), None);
/// ```
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SloClass {
    /// Tier name (stats, reports, metric labels).
    pub name: String,
    /// Total submit→response budget; `None` = best-effort (never shed by
    /// the deadline admission check, first to shed under overload if it
    /// is the lowest class).
    pub deadline: Option<Duration>,
}

impl SloClass {
    /// A tier with a hard latency budget.
    pub fn new(name: impl Into<String>, deadline: Duration) -> SloClass {
        SloClass { name: name.into(), deadline: Some(deadline) }
    }

    /// A tier with no deadline: admitted whenever a queue slot exists.
    pub fn best_effort(name: impl Into<String>) -> SloClass {
        SloClass { name: name.into(), deadline: None }
    }

    /// The deadline budget in microseconds, if any.
    pub fn deadline_us(&self) -> Option<u64> {
        self.deadline.map(|d| d.as_micros() as u64)
    }

    /// The default single-tier table used when a config names no classes.
    pub fn default_table() -> Vec<SloClass> {
        vec![SloClass::best_effort("default")]
    }
}

/// Parse a comma-separated class table, highest priority first. Each item
/// is `[name=]budget` where `budget` is a duration (`2500us`, `20ms`,
/// `1s`, or a bare microsecond count) or `best-effort`/`none`/`inf` for a
/// deadline-free tier. Unnamed tiers get `class<i>` names.
///
/// ```
/// use tvm_fpga_flow::coordinator::slo::parse_classes;
///
/// let t = parse_classes("gold=20ms,80ms,bulk=none").unwrap();
/// assert_eq!(t.len(), 3);
/// assert_eq!(t[0].name, "gold");
/// assert_eq!(t[1].name, "class1");
/// assert_eq!(t[1].deadline_us(), Some(80_000));
/// assert_eq!(t[2].deadline_us(), None);
/// ```
pub fn parse_classes(spec: &str) -> crate::Result<Vec<SloClass>> {
    let mut out = Vec::new();
    for (i, raw) in spec.split(',').enumerate() {
        let raw = raw.trim();
        if raw.is_empty() {
            continue;
        }
        let (name, budget) = match raw.split_once('=') {
            Some((n, b)) => (n.trim().to_string(), b.trim()),
            None => (format!("class{i}"), raw),
        };
        let deadline = parse_budget(budget)
            .map_err(|e| anyhow::anyhow!("class {i} ({name}): {e}"))?;
        out.push(SloClass { name, deadline });
    }
    anyhow::ensure!(!out.is_empty(), "class table is empty: {spec:?}");
    Ok(out)
}

/// Parse one deadline budget spelling (see [`parse_classes`]).
fn parse_budget(s: &str) -> Result<Option<Duration>, String> {
    let lower = s.to_ascii_lowercase();
    if matches!(lower.as_str(), "best-effort" | "besteffort" | "none" | "inf" | "0") {
        return Ok(None);
    }
    let (digits, mult_us) = if let Some(d) = lower.strip_suffix("us") {
        (d, 1.0)
    } else if let Some(d) = lower.strip_suffix("ms") {
        (d, 1e3)
    } else if let Some(d) = lower.strip_suffix('s') {
        (d, 1e6)
    } else {
        (lower.as_str(), 1.0)
    };
    let n: f64 = digits
        .trim()
        .parse()
        .map_err(|_| format!("bad deadline budget {s:?} (want e.g. 2500us, 20ms, 1s, none)"))?;
    if !(n > 0.0) || !n.is_finite() {
        return Err(format!("deadline budget must be positive: {s:?}"));
    }
    Ok(Some(Duration::from_micros((n * mult_us).round() as u64)))
}

/// Parse a comma-separated integer traffic mix (one weight per class),
/// e.g. `20,20,60`. Weights are relative, not percentages.
pub fn parse_mix(spec: &str) -> crate::Result<Vec<u32>> {
    let mix: Vec<u32> = spec
        .split(',')
        .map(|s| s.trim().parse::<u32>())
        .collect::<Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("bad class mix {spec:?} (want e.g. 20,20,60)"))?;
    anyhow::ensure!(mix.iter().any(|&w| w > 0), "class mix is all zeros: {spec:?}");
    Ok(mix)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_units_names_and_best_effort() {
        let t = parse_classes("interactive=2500us, 20ms ,bulk=best-effort").unwrap();
        assert_eq!(t[0], SloClass::new("interactive", Duration::from_micros(2500)));
        assert_eq!(t[1], SloClass::new("class1", Duration::from_millis(20)));
        assert_eq!(t[2], SloClass::best_effort("bulk"));
        // A bare number is microseconds; a bare `1s` is a second.
        let t = parse_classes("1500,1s").unwrap();
        assert_eq!(t[0].deadline_us(), Some(1500));
        assert_eq!(t[1].deadline_us(), Some(1_000_000));
    }

    #[test]
    fn rejects_garbage_and_empty() {
        assert!(parse_classes("").is_err());
        assert!(parse_classes("fast=quick").is_err());
        assert!(parse_classes("-3ms").is_err());
    }

    #[test]
    fn mix_parses_and_validates() {
        assert_eq!(parse_mix("20,20,60").unwrap(), vec![20, 20, 60]);
        assert!(parse_mix("0,0").is_err());
        assert!(parse_mix("a,b").is_err());
    }
}
