//! Pipeline-parallel serving: one worker thread per partition stage,
//! connected by bounded channels.
//!
//! ```text
//!  infer()/infer_async()      stage workers (one thread per device)
//!  ─────────────▶ sync_channel ─▶ [s0] ─chan─▶ [s1] ─chan─▶ [s2] ─▶ respond
//!       │          (queue_capacity)   bounded to channel_depth
//!       ▼
//!  Err(Overloaded) when full
//! ```
//!
//! This is the runtime half of a [`PipelinePlan`]: each stage worker
//! models one device holding a frame for its stage time
//! (`max(compute, transfer)` under the latency-balancing cost model),
//! then hands it to the next stage over a bounded channel. Frame *i + 1*
//! occupies stage 0 while frame *i* occupies stage 1, so steady-state
//! throughput is set by the slowest stage — exactly the quantity the cut
//! search minimizes — and a slow stage backs its predecessors up through
//! channel backpressure instead of deadlocking or buffering without
//! limit.
//!
//! The frame payload itself crosses the stages untouched (the per-stage
//! activations are modeled, not materialized), so the final stage's
//! deterministic prediction is the same FNV hash a whole-network
//! [`SimEngine`](super::SimEngine) would produce: a partitioned
//! deployment is observationally identical to an unpartitioned one,
//! frame for frame.
//!
//! Statistics reuse the serving [`Shared`] state with one "replica" per
//! stage, so [`StatsSnapshot`] carries per-stage frames, busy time and
//! occupancy, and [`StatsSnapshot::bottleneck`] attributes the pipeline
//! bottleneck. The accepted-implies-answered discipline matches
//! [`InferenceServer`](super::InferenceServer): shutdown drains in-flight
//! frames stage by stage, so the final snapshot satisfies
//! `completed == submitted`.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::flow::multi::PipelinePlan;
use crate::obs;

use super::engine::hash_predict;
use super::stats::Shared;
use super::{ServerError, SloClass, StatsSnapshot};

/// Timing model for one pipeline stage.
#[derive(Debug, Clone)]
pub struct StageSpec {
    /// Stage label, used as the replica name in [`StatsSnapshot`].
    pub name: String,
    /// Wall time the stage holds a frame: `max(compute, transfer)` when
    /// derived from a plan, or an injected duration in chaos tests.
    pub stage_time: Duration,
    /// Modeled bytes entering this stage over the host link (0 for
    /// stage 0, whose input arrives with the request).
    pub transfer_bytes: u64,
}

/// Configuration for [`PipelineServer::start`].
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// One spec per stage, in pipeline order. Must be non-empty.
    pub stages: Vec<StageSpec>,
    /// Expected elements per submitted frame (stage 0's input).
    pub frame_elems: usize,
    /// Classes the final stage predicts over.
    pub num_classes: usize,
    /// Bound of each inter-stage channel — how far a fast stage may run
    /// ahead of its successor before blocking.
    pub channel_depth: usize,
    /// Bound of the entry queue; a full queue rejects with
    /// [`ServerError::Overloaded`].
    pub queue_capacity: usize,
    /// Divides every stage time (tests use large scales to serve modeled
    /// millisecond stages in microseconds).
    pub time_scale: f64,
    /// SLO class table, highest priority first. Empty = one best-effort
    /// class.
    pub classes: Vec<SloClass>,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            stages: Vec::new(),
            frame_elems: 16,
            num_classes: 10,
            channel_depth: 2,
            queue_capacity: 64,
            time_scale: 1.0,
            classes: Vec::new(),
        }
    }
}

impl PipelineConfig {
    /// Derive stage timing from a compiled [`PipelinePlan`]: one stage
    /// per plan stage, named `"{stage}@{target}"`, holding frames for the
    /// stage's modeled `max(compute, transfer)` time.
    pub fn from_plan(plan: &PipelinePlan) -> PipelineConfig {
        let stages = plan
            .stages
            .iter()
            .map(|st| StageSpec {
                name: format!("{}@{}", st.graph.name, st.target.name),
                stage_time: Duration::from_secs_f64(st.cost.stage_s()),
                transfer_bytes: st.cost.transfer_bytes,
            })
            .collect();
        let first = &plan.stages[0].graph;
        let last = &plan.stages[plan.stages.len() - 1].graph;
        PipelineConfig {
            stages,
            frame_elems: first.nodes[first.input].shape.elems(),
            num_classes: last.nodes[last.output].shape.elems(),
            ..PipelineConfig::default()
        }
    }

    /// Divide all stage times by `scale` (like
    /// [`SimEngine::with_time_scale`](super::SimEngine::with_time_scale)).
    pub fn with_time_scale(mut self, scale: f64) -> PipelineConfig {
        self.time_scale = scale;
        self
    }
}

/// A frame in flight through the stage chain.
struct PipeFrame {
    frame: Vec<f32>,
    /// Index into the server's SLO class table.
    class: usize,
    submitted: Instant,
    /// Stamped when stage 0 dequeues the frame (queue → execute split).
    dispatched: Option<Instant>,
    resp: std::sync::mpsc::Sender<crate::Result<u32>>,
}

/// A running stage pipeline. See the [module docs](self) for the thread
/// and channel layout.
pub struct PipelineServer {
    /// Entry channel; `None` once shutdown has closed it.
    input: Option<SyncSender<PipeFrame>>,
    workers: Vec<JoinHandle<()>>,
    shared: Arc<Shared>,
    capacity: usize,
    frame_elems: usize,
}

impl PipelineServer {
    /// Spawn one worker thread per stage, wired by bounded channels.
    pub fn start(cfg: PipelineConfig) -> crate::Result<PipelineServer> {
        anyhow::ensure!(!cfg.stages.is_empty(), "pipeline needs at least one stage");
        anyhow::ensure!(cfg.num_classes > 0, "pipeline needs at least one output class");
        anyhow::ensure!(cfg.time_scale > 0.0, "time_scale must be positive");
        let capacity = cfg.queue_capacity.max(1);
        let depth = cfg.channel_depth.max(1);
        let classes =
            if cfg.classes.is_empty() { SloClass::default_table() } else { cfg.classes.clone() };
        let shared = Arc::new(Shared::with_classes(
            cfg.stages.iter().map(|s| s.name.clone()).collect(),
            1,
            &classes,
        ));

        let n = cfg.stages.len();
        let (entry_tx, entry_rx) = sync_channel::<PipeFrame>(capacity);
        let mut rx = entry_rx;
        let mut workers = Vec::with_capacity(n);
        for (index, spec) in cfg.stages.into_iter().enumerate() {
            let last = index + 1 == n;
            let (next_tx, next_rx) = if last {
                (None, None)
            } else {
                let (t, r) = sync_channel::<PipeFrame>(depth);
                (Some(t), Some(r))
            };
            let stage_rx = rx;
            let shared = Arc::clone(&shared);
            let scale = cfg.time_scale;
            let classes = cfg.num_classes;
            workers.push(std::thread::spawn(move || {
                stage_worker(index, spec, stage_rx, next_tx, shared, scale, classes);
            }));
            rx = match next_rx {
                Some(r) => r,
                None => break,
            };
        }

        Ok(PipelineServer {
            input: Some(entry_tx),
            workers,
            shared,
            capacity,
            frame_elems: cfg.frame_elems,
        })
    }

    /// [`PipelineServer::start`] from a compiled plan, at real time.
    pub fn from_plan(plan: &PipelinePlan) -> crate::Result<PipelineServer> {
        PipelineServer::start(PipelineConfig::from_plan(plan))
    }

    /// Submit one frame at the highest priority and block for its
    /// prediction.
    pub fn infer(&self, frame: Vec<f32>) -> crate::Result<u32> {
        self.infer_class(frame, 0)
    }

    /// Submit one frame asynchronously at the highest priority.
    pub fn infer_async(
        &self,
        frame: Vec<f32>,
    ) -> crate::Result<Receiver<crate::Result<u32>>> {
        self.infer_class_async(frame, 0)
    }

    /// Submit under the given SLO class (clamped) and block.
    pub fn infer_class(&self, frame: Vec<f32>, class: usize) -> crate::Result<u32> {
        let rx = self.infer_class_async(frame, class)?;
        rx.recv().unwrap_or_else(|_| Err(ServerError::Stopped.into()))
    }

    /// Submit one frame under the given SLO class; the returned channel
    /// yields the prediction. Sheds before queueing with
    /// [`ServerError::DeadlineUnmeetable`] when the class deadline is
    /// smaller than the predicted latency, fails fast with
    /// [`ServerError::Overloaded`] when the entry queue is full, and with
    /// [`ServerError::BadFrame`] on a size mismatch. (The entry channel
    /// cannot reorder in-flight frames, so unlike
    /// [`InferenceServer`](super::InferenceServer) a full pipeline sheds
    /// the *arriving* request regardless of class.)
    pub fn infer_class_async(
        &self,
        frame: Vec<f32>,
        class: usize,
    ) -> crate::Result<Receiver<crate::Result<u32>>> {
        let input = match &self.input {
            Some(tx) => tx,
            None => return Err(ServerError::Stopped.into()),
        };
        if frame.len() != self.frame_elems {
            return Err(ServerError::BadFrame {
                expected: self.frame_elems,
                got: frame.len(),
            }
            .into());
        }
        let class = class.min(self.shared.classes.len() - 1);
        let cs = &self.shared.classes[class];
        if let Some(deadline_us) = cs.deadline_us {
            let predicted_us = self.shared.predicted_total_us();
            if predicted_us > deadline_us {
                cs.shed_deadline.fetch_add(1, Ordering::Relaxed);
                self.shared.deadline_rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ServerError::DeadlineUnmeetable { deadline_us, predicted_us }.into());
            }
        }
        let (resp, rx) = channel();
        // Count before pushing so `completed` can never outrun `submitted`.
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        cs.submitted.fetch_add(1, Ordering::Relaxed);
        let f =
            PipeFrame { frame, class, submitted: Instant::now(), dispatched: None, resp };
        match input.try_send(f) {
            Ok(()) => Ok(rx),
            Err(TrySendError::Full(_)) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed);
                cs.submitted.fetch_sub(1, Ordering::Relaxed);
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                cs.shed_overload.fetch_add(1, Ordering::Relaxed);
                Err(ServerError::Overloaded { capacity: self.capacity }.into())
            }
            Err(TrySendError::Disconnected(_)) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed);
                cs.submitted.fetch_sub(1, Ordering::Relaxed);
                Err(ServerError::Stopped.into())
            }
        }
    }

    /// Point-in-time statistics (per-stage entries under `replicas`).
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.snapshot()
    }

    /// Close the entry queue, drain every in-flight frame through the
    /// remaining stages, join the workers and return the final snapshot
    /// (`completed == submitted`).
    /// The occupancy denominator freezes here, like
    /// [`InferenceServer::shutdown`](super::InferenceServer::shutdown).
    pub fn shutdown(mut self) -> StatsSnapshot {
        self.input.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shared.freeze_uptime();
        self.shared.snapshot()
    }
}

impl Drop for PipelineServer {
    fn drop(&mut self) {
        // Close the entry channel; detached workers drain and exit in
        // cascade as each upstream sender drops.
        self.input.take();
    }
}

fn stage_worker(
    index: usize,
    spec: StageSpec,
    rx: Receiver<PipeFrame>,
    next: Option<SyncSender<PipeFrame>>,
    shared: Arc<Shared>,
    scale: f64,
    classes: usize,
) {
    let stage_time = Duration::from_secs_f64(spec.stage_time.as_secs_f64() / scale);
    while let Ok(mut req) = rx.recv() {
        let mut span = obs::span("pipeline", &spec.name);
        span.set_arg("stage", index as u64);
        let t0 = Instant::now();
        if index == 0 {
            req.dispatched = Some(t0);
            let queued = req.submitted.elapsed().as_micros() as u64;
            let recent = {
                let mut ql = shared.queue_latency.lock().unwrap();
                ql.record(queued);
                ql.recent_percentile(super::stats::RECENT_WINDOW, 99.0)
            };
            if let Some(p) = recent {
                shared.queue_p99_recent_us.store(p.max(1), Ordering::Relaxed);
            }
        }
        if !stage_time.is_zero() {
            std::thread::sleep(stage_time);
        }
        let rep = &shared.replicas[index];
        rep.batches.fetch_add(1, Ordering::Relaxed);
        rep.frames.fetch_add(1, Ordering::Relaxed);
        rep.busy_us.fetch_add(t0.elapsed().as_micros() as u64, Ordering::Relaxed);
        match &next {
            Some(tx) => {
                // Blocks when the successor's channel is full: that is the
                // backpressure that makes the slowest stage set throughput.
                if tx.send(req).is_err() {
                    break; // downstream worker gone; nothing left to feed
                }
            }
            None => {
                let pred = hash_predict(&req.frame, classes);
                let done = Instant::now();
                let total = done.saturating_duration_since(req.submitted).as_micros() as u64;
                shared.latency.lock().unwrap().record(total);
                shared.completed.fetch_add(1, Ordering::Relaxed);
                if let Some(cs) =
                    shared.classes.get(req.class.min(shared.classes.len().saturating_sub(1)))
                {
                    cs.latency.lock().unwrap().record(total);
                    cs.completed.fetch_add(1, Ordering::Relaxed);
                }
                if let Some(d) = req.dispatched {
                    shared
                        .record_exec_ewma(done.saturating_duration_since(d).as_micros() as u64);
                }
                if obs::enabled() {
                    obs::global_metrics()
                        .counter(
                            "flow_pipeline_frames_total",
                            "frames completing the stage pipeline",
                        )
                        .inc();
                }
                let _ = req.resp.send(Ok(pred));
            }
        }
    }
}

/// Export pipeline-shaped metrics from a final snapshot: the standard
/// `flow_serve_*` gauges plus per-stage occupancy and bottleneck
/// attribution.
pub fn export_pipeline_metrics(reg: &crate::obs::Registry, s: &StatsSnapshot) {
    s.export_metrics(reg);
    reg.set_gauge(
        "flow_pipeline_stage_count",
        "pipeline stages serving",
        s.replicas.len() as f64,
    );
    if let Some(b) = s.bottleneck() {
        reg.set_gauge(
            "flow_pipeline_bottleneck_stage",
            "index of the busiest pipeline stage",
            b as f64,
        );
    }
    for (i, r) in s.replicas.iter().enumerate() {
        reg.set_gauge(
            &format!("flow_pipeline_stage_{i}_occupancy"),
            &format!("busy fraction of pipeline stage {}", r.name),
            r.occupancy,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::super::SimEngine;
    use super::*;
    use crate::flow::multi::Link;
    use crate::graph::models::lenet5;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    fn spec(name: &str, stage_time: Duration) -> StageSpec {
        StageSpec { name: name.to_string(), stage_time, transfer_bytes: 0 }
    }

    fn frame(elems: usize, salt: f32) -> Vec<f32> {
        (0..elems).map(|i| i as f32 * 0.25 + salt).collect()
    }

    #[test]
    fn pipeline_answers_match_whole_network_sim_engine() {
        let cfg = PipelineConfig {
            stages: vec![spec("s0", ms(0)), spec("s1", ms(0)), spec("s2", ms(0))],
            frame_elems: 12,
            num_classes: 7,
            ..PipelineConfig::default()
        };
        let server = PipelineServer::start(cfg).unwrap();
        let whole =
            SimEngine::new("whole", 12, 7, 1, Duration::ZERO, Duration::ZERO);
        for salt in 0..5 {
            let f = frame(12, salt as f32);
            let got = server.infer(f.clone()).unwrap();
            let want =
                crate::coordinator::Engine::classify_batch(&whole, &[f.as_slice()]).unwrap()[0];
            assert_eq!(got, want, "partitioned prediction must match whole-network sim");
        }
        let s = server.shutdown();
        assert_eq!(s.submitted, 5);
        assert_eq!(s.completed, 5);
        assert_eq!(s.replicas.len(), 3);
        for r in &s.replicas {
            assert_eq!(r.frames, 5, "every stage sees every frame");
        }
    }

    #[test]
    fn slow_stage_is_attributed_as_bottleneck() {
        let cfg = PipelineConfig {
            stages: vec![spec("fast0", ms(1)), spec("slow", ms(8)), spec("fast1", ms(1))],
            frame_elems: 4,
            num_classes: 10,
            ..PipelineConfig::default()
        };
        let server = PipelineServer::start(cfg).unwrap();
        let pending: Vec<_> =
            (0..10).map(|i| server.infer_async(frame(4, i as f32)).unwrap()).collect();
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let s = server.shutdown();
        assert_eq!(s.completed, 10);
        assert_eq!(s.bottleneck(), Some(1), "busy time must point at the slow stage");
        let slow = &s.replicas[1];
        assert!(
            slow.busy_us > s.replicas[0].busy_us && slow.busy_us > s.replicas[2].busy_us,
            "slow stage accumulates the most busy time: {:?}",
            s.replicas
        );
    }

    #[test]
    fn full_entry_queue_rejects_with_overloaded() {
        let cfg = PipelineConfig {
            stages: vec![spec("s0", ms(50))],
            frame_elems: 4,
            num_classes: 3,
            channel_depth: 1,
            queue_capacity: 1,
            ..PipelineConfig::default()
        };
        let server = PipelineServer::start(cfg).unwrap();
        let mut pending = Vec::new();
        let mut overloaded = 0;
        for i in 0..8 {
            match server.infer_async(frame(4, i as f32)) {
                Ok(rx) => pending.push(rx),
                Err(e) => {
                    assert!(matches!(
                        e.downcast_ref::<ServerError>(),
                        Some(ServerError::Overloaded { capacity: 1 })
                    ));
                    overloaded += 1;
                }
            }
        }
        assert!(overloaded > 0, "a bounded queue must shed load");
        for rx in pending {
            rx.recv().unwrap().unwrap();
        }
        let s = server.shutdown();
        assert_eq!(s.completed, s.submitted);
        assert_eq!(s.rejected, overloaded);
    }

    #[test]
    fn bad_frame_and_stopped_errors_surface() {
        let cfg = PipelineConfig {
            stages: vec![spec("s0", ms(0))],
            frame_elems: 8,
            num_classes: 4,
            ..PipelineConfig::default()
        };
        let server = PipelineServer::start(cfg).unwrap();
        let err = server.infer(frame(5, 0.0)).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ServerError>(),
            Some(ServerError::BadFrame { expected: 8, got: 5 })
        ));
        let s = server.shutdown();
        assert_eq!(s.submitted, 0);
    }

    #[test]
    fn pipeline_tracks_per_class_stats_and_sheds_unmeetable_deadlines() {
        let cfg = PipelineConfig {
            stages: vec![spec("s0", ms(2))],
            frame_elems: 4,
            num_classes: 5,
            classes: vec![
                SloClass::new("tight", Duration::from_micros(1)),
                SloClass::best_effort("bulk"),
            ],
            ..PipelineConfig::default()
        };
        let server = PipelineServer::start(cfg).unwrap();
        // Prime the admission signals: bulk traffic records queue latency
        // and execution time (a 2 ms stage dwarfs the 1 µs budget).
        for i in 0..6 {
            server.infer_class(frame(4, i as f32), 1).unwrap();
        }
        let err = server.infer_class(frame(4, 9.0), 0).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<ServerError>(),
                Some(ServerError::DeadlineUnmeetable { deadline_us: 1, .. })
            ),
            "{err}"
        );
        let s = server.shutdown();
        assert_eq!(s.completed, 6);
        assert_eq!(s.deadline_rejected, 1);
        assert_eq!(s.classes[0].shed_deadline, 1);
        assert_eq!(s.classes[0].completed, 0);
        assert_eq!(s.classes[1].completed, 6);
        // Shed-before-queue: the refused request recorded no queue latency.
        assert_eq!(s.queue_samples, s.completed);
    }

    #[test]
    fn config_from_plan_serves_partitioned_lenet() {
        let g = lenet5();
        let plan =
            PipelinePlan::build(&g, &["stratix10sx", "stratix10sx"], &Link::default()).unwrap();
        assert_eq!(plan.stages.len(), 2);
        let cfg = PipelineConfig::from_plan(&plan).with_time_scale(1e4);
        assert_eq!(cfg.frame_elems, g.nodes[g.input].shape.elems());
        assert_eq!(cfg.num_classes, 10);
        assert_eq!(cfg.stages[0].transfer_bytes, 0);
        assert!(cfg.stages[1].transfer_bytes > 0, "stage 1 pays the boundary transfer");
        let server = PipelineServer::start(cfg).unwrap();
        for i in 0..4 {
            server.infer(frame(g.nodes[g.input].shape.elems(), i as f32)).unwrap();
        }
        let s = server.shutdown();
        assert_eq!(s.completed, 4);
        assert_eq!(s.replicas.len(), 2);
        assert!(s.replicas[0].name.contains("stratix10sx"));
        assert!(s.bottleneck().is_some());

        let reg = crate::obs::Registry::default();
        export_pipeline_metrics(&reg, &s);
        let text = reg.render_prometheus();
        assert!(text.contains("flow_pipeline_stage_count 2"));
        assert!(text.contains("flow_pipeline_stage_0_occupancy"));
        assert!(text.contains("flow_pipeline_bottleneck_stage"));
    }
}
