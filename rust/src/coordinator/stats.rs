//! Serving statistics: counters, latency distributions, batch-size
//! histogram and per-replica occupancy.
//!
//! Two latency distributions are kept. *Queue* latency (submit → dispatch)
//! is the price of batching and backpressure; *total* latency (submit →
//! response) adds execution. Comparing the two shows whether a latency
//! problem is a scheduling problem or an engine problem.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::metrics::{BatchHistogram, LatencyStats};

/// Point-in-time view of a running (or just-shut-down) server.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Responses delivered (successes *and* engine errors).
    pub completed: u64,
    /// Requests refused with `ServerError::Overloaded` (not in `submitted`).
    pub rejected: u64,
    /// Batches executed across all replicas.
    pub batches: u64,
    /// Frames that ran inside multi-frame batches.
    pub batched_frames: u64,
    /// Total submit→response latency.
    pub p50_us: Option<u64>,
    pub p99_us: Option<u64>,
    pub mean_us: Option<f64>,
    /// Submit→dispatch (time spent queued, the batching delay).
    pub queue_p50_us: Option<u64>,
    pub queue_p99_us: Option<u64>,
    /// `batch_hist[i]` = number of executed batches of size `i + 1`.
    pub batch_hist: Vec<u64>,
    /// One entry per replica, in spec order.
    pub replicas: Vec<ReplicaStats>,
}

impl StatsSnapshot {
    /// Compact `size×count` rendering of the batch histogram.
    pub fn batch_hist_render(&self) -> String {
        BatchHistogram::from_counts(self.batch_hist.clone()).render()
    }

    /// Index of the busiest replica (or pipeline stage) — the one with the
    /// most accumulated busy time. `None` until some replica has done work.
    /// Ties resolve to the earliest index so attribution is deterministic.
    pub fn bottleneck(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if r.busy_us > 0 && best.is_none_or(|b| r.busy_us > self.replicas[b].busy_us) {
                best = Some(i);
            }
        }
        best
    }

    /// Mean frames per executed batch (0.0 before any batch ran).
    pub fn mean_batch_size(&self) -> f64 {
        let frames: u64 =
            self.batch_hist.iter().enumerate().map(|(i, n)| (i as u64 + 1) * n).sum();
        if self.batches == 0 {
            0.0
        } else {
            frames as f64 / self.batches as f64
        }
    }

    /// Re-register this snapshot as first-class metrics: gauges for the
    /// counters and latency percentiles, the batch-size histogram as a
    /// real [`crate::obs::Histogram`] (one bucket per size). Gauges are
    /// last-write-wins, but the histogram import is cumulative — call
    /// once per run (the `profile`/`serve` exports do, at shutdown).
    pub fn export_metrics(&self, reg: &crate::obs::Registry) {
        reg.set_gauge("flow_serve_submitted", "requests accepted into the queue", self.submitted as f64);
        reg.set_gauge("flow_serve_completed", "responses delivered", self.completed as f64);
        reg.set_gauge("flow_serve_rejected", "requests shed by backpressure", self.rejected as f64);
        reg.set_gauge("flow_serve_batches", "batches executed", self.batches as f64);
        reg.set_gauge("flow_serve_batched_frames", "frames inside multi-frame batches", self.batched_frames as f64);
        reg.set_gauge("flow_serve_mean_batch_size", "mean frames per executed batch", self.mean_batch_size());
        if let Some(p) = self.p50_us {
            reg.set_gauge("flow_serve_latency_p50_us", "submit-to-response p50", p as f64);
        }
        if let Some(p) = self.p99_us {
            reg.set_gauge("flow_serve_latency_p99_us", "submit-to-response p99", p as f64);
        }
        if let Some(m) = self.mean_us {
            reg.set_gauge("flow_serve_latency_mean_us", "submit-to-response mean", m);
        }
        if let Some(p) = self.queue_p50_us {
            reg.set_gauge("flow_serve_queue_latency_p50_us", "submit-to-dispatch p50", p as f64);
        }
        if let Some(p) = self.queue_p99_us {
            reg.set_gauge("flow_serve_queue_latency_p99_us", "submit-to-dispatch p99", p as f64);
        }
        if !self.batch_hist.is_empty() {
            let bounds: Vec<f64> = (1..=self.batch_hist.len()).map(|i| i as f64).collect();
            let h = reg.histogram("flow_serve_batch_size", "frames per executed batch", &bounds);
            for (i, &n) in self.batch_hist.iter().enumerate() {
                h.observe_n((i + 1) as f64, n);
            }
        }
        for (i, r) in self.replicas.iter().enumerate() {
            reg.set_gauge(
                &format!("flow_serve_replica_{i}_frames"),
                &format!("frames executed by replica {}", r.name),
                r.frames as f64,
            );
            reg.set_gauge(
                &format!("flow_serve_replica_{i}_occupancy"),
                &format!("busy fraction of replica {}", r.name),
                r.occupancy,
            );
        }
    }
}

/// Per-replica serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ReplicaStats {
    pub name: String,
    /// Batches this replica executed.
    pub batches: u64,
    /// Frames this replica executed.
    pub frames: u64,
    /// Wall time spent executing batches, in microseconds.
    pub busy_us: u64,
    /// `busy_us` over the server's uptime: 0.0 = idle, ~1.0 = saturated.
    pub occupancy: f64,
}

pub(crate) struct ReplicaShared {
    pub(crate) name: String,
    pub(crate) batches: AtomicU64,
    pub(crate) frames: AtomicU64,
    pub(crate) busy_us: AtomicU64,
}

/// Shared server-wide counters, written by submitters, the dispatcher and
/// every replica worker.
pub(crate) struct Shared {
    pub(crate) started: Instant,
    pub(crate) submitted: AtomicU64,
    pub(crate) completed: AtomicU64,
    pub(crate) rejected: AtomicU64,
    pub(crate) batches: AtomicU64,
    pub(crate) batched_frames: AtomicU64,
    pub(crate) latency: Mutex<LatencyStats>,
    pub(crate) queue_latency: Mutex<LatencyStats>,
    pub(crate) batch_hist: Mutex<BatchHistogram>,
    pub(crate) replicas: Vec<ReplicaShared>,
}

impl Shared {
    pub(crate) fn new(replica_names: Vec<String>, max_batch: usize) -> Shared {
        Shared {
            started: Instant::now(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_frames: AtomicU64::new(0),
            latency: Mutex::new(LatencyStats::default()),
            queue_latency: Mutex::new(LatencyStats::default()),
            batch_hist: Mutex::new(BatchHistogram::new(max_batch)),
            replicas: replica_names
                .into_iter()
                .map(|name| ReplicaShared {
                    name,
                    batches: AtomicU64::new(0),
                    frames: AtomicU64::new(0),
                    busy_us: AtomicU64::new(0),
                })
                .collect(),
        }
    }

    pub(crate) fn snapshot(&self) -> StatsSnapshot {
        let latency = self.latency.lock().unwrap();
        let queue = self.queue_latency.lock().unwrap();
        let uptime_us = self.started.elapsed().as_micros().max(1) as u64;
        StatsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_frames: self.batched_frames.load(Ordering::Relaxed),
            p50_us: latency.percentile(50.0),
            p99_us: latency.percentile(99.0),
            mean_us: latency.mean(),
            queue_p50_us: queue.percentile(50.0),
            queue_p99_us: queue.percentile(99.0),
            batch_hist: self.batch_hist.lock().unwrap().counts().to_vec(),
            replicas: self
                .replicas
                .iter()
                .map(|r| {
                    let busy_us = r.busy_us.load(Ordering::Relaxed);
                    ReplicaStats {
                        name: r.name.clone(),
                        batches: r.batches.load(Ordering::Relaxed),
                        frames: r.frames.load(Ordering::Relaxed),
                        busy_us,
                        occupancy: busy_us as f64 / uptime_us as f64,
                    }
                })
                .collect(),
        }
    }
}
