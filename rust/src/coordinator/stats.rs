//! Serving statistics: counters, latency distributions, batch-size
//! histogram, per-replica occupancy and per-SLO-class accounting.
//!
//! Two latency distributions are kept. *Queue* latency (submit → dispatch)
//! is the price of batching and backpressure; *total* latency (submit →
//! response) adds execution. Comparing the two shows whether a latency
//! problem is a scheduling problem or an engine problem.
//!
//! Admission control reads two extra low-cost signals maintained here:
//! a recent-window queue-latency p99 and an EWMA of per-request execution
//! time, published as atomics so the submit path never takes the latency
//! locks.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::SloClass;
use crate::metrics::{BatchHistogram, LatencyStats};

/// Point-in-time view of a running (or just-shut-down) server.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Responses delivered (successes *and* engine errors).
    pub completed: u64,
    /// Requests shed with `ServerError::Overloaded` (not in `submitted`) —
    /// refused pushes plus lower-priority evictions.
    pub rejected: u64,
    /// Requests shed *before queueing* with `ServerError::DeadlineUnmeetable`
    /// (not in `submitted`, disjoint from `rejected`).
    pub deadline_rejected: u64,
    /// Batches executed across all replicas.
    pub batches: u64,
    /// Frames that ran inside multi-frame batches.
    pub batched_frames: u64,
    /// Total submit→response latency.
    pub p50_us: Option<u64>,
    pub p99_us: Option<u64>,
    pub mean_us: Option<f64>,
    /// Submit→dispatch (time spent queued, the batching delay).
    pub queue_p50_us: Option<u64>,
    pub queue_p99_us: Option<u64>,
    /// Queue-latency p99 over the most recent dispatch window — the
    /// admission-control and autoscaling signal (decays after a burst,
    /// unlike the run-cumulative `queue_p99_us`).
    pub queue_p99_recent_us: Option<u64>,
    /// Queue-latency samples recorded. Only *dispatched* requests record
    /// queue latency, so `queue_samples == completed` proves shed requests
    /// never occupied the queue (shed-before-queue).
    pub queue_samples: u64,
    /// `batch_hist[i]` = number of executed batches of size `i + 1`.
    pub batch_hist: Vec<u64>,
    /// Replicas currently receiving new batches (≤ `replicas.len()`).
    pub active_replicas: u64,
    /// Autoscaler activations / deactivations applied this run.
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// One entry per replica, in spec order.
    pub replicas: Vec<ReplicaStats>,
    /// One entry per SLO class, priority order (index = priority).
    pub classes: Vec<ClassStats>,
}

impl StatsSnapshot {
    /// Compact `size×count` rendering of the batch histogram.
    pub fn batch_hist_render(&self) -> String {
        BatchHistogram::from_counts(self.batch_hist.clone()).render()
    }

    /// Index of the busiest replica (or pipeline stage) — the one with the
    /// most accumulated busy time. `None` until some replica has done work.
    /// Ties resolve to the earliest index so attribution is deterministic.
    pub fn bottleneck(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, r) in self.replicas.iter().enumerate() {
            if r.busy_us > 0 && best.is_none_or(|b| r.busy_us > self.replicas[b].busy_us) {
                best = Some(i);
            }
        }
        best
    }

    /// Mean frames per executed batch (0.0 before any batch ran).
    pub fn mean_batch_size(&self) -> f64 {
        let frames: u64 =
            self.batch_hist.iter().enumerate().map(|(i, n)| (i as u64 + 1) * n).sum();
        if self.batches == 0 {
            0.0
        } else {
            frames as f64 / self.batches as f64
        }
    }

    /// Total requests shed for any reason (overload + deadline).
    pub fn total_shed(&self) -> u64 {
        self.rejected + self.deadline_rejected
    }

    /// Re-register this snapshot as first-class metrics: gauges for the
    /// counters and latency percentiles, the batch-size histogram as a
    /// real [`crate::obs::Histogram`] (one bucket per size). The export is
    /// idempotent: gauges are last-write-wins and the histogram import
    /// only adds the *delta* against what the registry already holds, so
    /// periodic re-export during a run never double-counts.
    pub fn export_metrics(&self, reg: &crate::obs::Registry) {
        reg.set_gauge("flow_serve_submitted", "requests accepted into the queue", self.submitted as f64);
        reg.set_gauge("flow_serve_completed", "responses delivered", self.completed as f64);
        reg.set_gauge("flow_serve_rejected", "requests shed by backpressure", self.rejected as f64);
        reg.set_gauge(
            "flow_serve_deadline_rejected",
            "requests shed before queueing (deadline unmeetable)",
            self.deadline_rejected as f64,
        );
        reg.set_gauge("flow_serve_batches", "batches executed", self.batches as f64);
        reg.set_gauge("flow_serve_batched_frames", "frames inside multi-frame batches", self.batched_frames as f64);
        reg.set_gauge("flow_serve_mean_batch_size", "mean frames per executed batch", self.mean_batch_size());
        reg.set_gauge("flow_serve_active_replicas", "replicas receiving new batches", self.active_replicas as f64);
        reg.set_gauge("flow_serve_scale_ups", "autoscaler activations", self.scale_ups as f64);
        reg.set_gauge("flow_serve_scale_downs", "autoscaler deactivations", self.scale_downs as f64);
        if let Some(p) = self.p50_us {
            reg.set_gauge("flow_serve_latency_p50_us", "submit-to-response p50", p as f64);
        }
        if let Some(p) = self.p99_us {
            reg.set_gauge("flow_serve_latency_p99_us", "submit-to-response p99", p as f64);
        }
        if let Some(m) = self.mean_us {
            reg.set_gauge("flow_serve_latency_mean_us", "submit-to-response mean", m);
        }
        if let Some(p) = self.queue_p50_us {
            reg.set_gauge("flow_serve_queue_latency_p50_us", "submit-to-dispatch p50", p as f64);
        }
        if let Some(p) = self.queue_p99_us {
            reg.set_gauge("flow_serve_queue_latency_p99_us", "submit-to-dispatch p99", p as f64);
        }
        if !self.batch_hist.is_empty() {
            let bounds: Vec<f64> = (1..=self.batch_hist.len()).map(|i| i as f64).collect();
            let h = reg.histogram("flow_serve_batch_size", "frames per executed batch", &bounds);
            // Delta import: bucket for size i+1 is index i (bounds are
            // 1..=len). Adding only what the registry has not yet seen
            // keeps repeated exports from double-counting.
            let have = h.bucket_counts();
            for (i, &n) in self.batch_hist.iter().enumerate() {
                let already = have.get(i).copied().unwrap_or(0);
                if n > already {
                    h.observe_n((i + 1) as f64, n - already);
                }
            }
        }
        for (i, r) in self.replicas.iter().enumerate() {
            reg.set_gauge(
                &format!("flow_serve_replica_{i}_frames"),
                &format!("frames executed by replica {}", r.name),
                r.frames as f64,
            );
            reg.set_gauge(
                &format!("flow_serve_replica_{i}_occupancy"),
                &format!("busy fraction of replica {}", r.name),
                r.occupancy,
            );
        }
        for (i, c) in self.classes.iter().enumerate() {
            reg.set_gauge(
                &format!("flow_serve_class_{i}_completed"),
                &format!("responses delivered for class {}", c.name),
                c.completed as f64,
            );
            reg.set_gauge(
                &format!("flow_serve_class_{i}_shed"),
                &format!("requests shed for class {}", c.name),
                c.shed_total() as f64,
            );
            if let Some(p) = c.p99_us {
                reg.set_gauge(
                    &format!("flow_serve_class_{i}_latency_p99_us"),
                    &format!("submit-to-response p99 for class {}", c.name),
                    p as f64,
                );
            }
        }
    }
}

/// Per-replica serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ReplicaStats {
    pub name: String,
    /// Batches this replica executed.
    pub batches: u64,
    /// Frames this replica executed.
    pub frames: u64,
    /// Wall time spent executing batches, in microseconds.
    pub busy_us: u64,
    /// `busy_us` over the server's uptime: 0.0 = idle, ~1.0 = saturated.
    pub occupancy: f64,
}

/// Per-SLO-class serving statistics (index in
/// [`StatsSnapshot::classes`] = priority, 0 highest).
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    pub name: String,
    /// Deadline budget, if the class has one.
    pub deadline_us: Option<u64>,
    /// Requests of this class accepted into the queue.
    pub submitted: u64,
    /// Responses delivered for this class.
    pub completed: u64,
    /// Shed under queue pressure (refused or evicted), answered
    /// `Overloaded`.
    pub shed_overload: u64,
    /// Shed before queueing, answered `DeadlineUnmeetable`.
    pub shed_deadline: u64,
    /// Submit→response percentiles for this class alone.
    pub p50_us: Option<u64>,
    pub p99_us: Option<u64>,
}

impl ClassStats {
    /// Requests of this class shed for any reason.
    pub fn shed_total(&self) -> u64 {
        self.shed_overload + self.shed_deadline
    }

    /// Shed fraction of everything offered to this class (0.0 when idle).
    pub fn shed_rate(&self) -> f64 {
        let offered = self.submitted + self.shed_total();
        if offered == 0 {
            0.0
        } else {
            self.shed_total() as f64 / offered as f64
        }
    }

    /// Whether the completed-request p99 met the class deadline (vacuously
    /// true for best-effort classes or before any completion).
    pub fn slo_met(&self) -> bool {
        match (self.deadline_us, self.p99_us) {
            (Some(budget), Some(p99)) => p99 <= budget,
            _ => true,
        }
    }
}

pub(crate) struct ReplicaShared {
    pub(crate) name: String,
    pub(crate) batches: AtomicU64,
    pub(crate) frames: AtomicU64,
    pub(crate) busy_us: AtomicU64,
}

/// Per-class shared counters (see [`ClassStats`] for field meanings).
pub(crate) struct ClassShared {
    pub(crate) name: String,
    pub(crate) deadline_us: Option<u64>,
    pub(crate) submitted: AtomicU64,
    pub(crate) completed: AtomicU64,
    pub(crate) shed_overload: AtomicU64,
    pub(crate) shed_deadline: AtomicU64,
    pub(crate) latency: Mutex<LatencyStats>,
}

impl ClassShared {
    fn new(c: &SloClass) -> ClassShared {
        ClassShared {
            name: c.name.clone(),
            deadline_us: c.deadline_us(),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            shed_overload: AtomicU64::new(0),
            shed_deadline: AtomicU64::new(0),
            latency: Mutex::new(LatencyStats::default()),
        }
    }
}

/// How many trailing queue-latency samples feed the recent-window p99.
pub(crate) const RECENT_WINDOW: usize = 128;

/// Shared server-wide counters, written by submitters, the dispatcher and
/// every replica worker.
pub(crate) struct Shared {
    pub(crate) started: Instant,
    /// Uptime in µs frozen at shutdown; 0 while running. Post-drain
    /// snapshots divide occupancy by the frozen value so it stops decaying
    /// once the server is down.
    pub(crate) uptime_frozen_us: AtomicU64,
    pub(crate) submitted: AtomicU64,
    pub(crate) completed: AtomicU64,
    pub(crate) rejected: AtomicU64,
    pub(crate) deadline_rejected: AtomicU64,
    pub(crate) batches: AtomicU64,
    pub(crate) batched_frames: AtomicU64,
    /// Admission signals: recent queue-latency p99 (dispatcher-maintained)
    /// and an EWMA of per-request execution time (worker-maintained), both
    /// µs. Zero means "no signal yet" — admission then admits.
    pub(crate) queue_p99_recent_us: AtomicU64,
    pub(crate) exec_ewma_us: AtomicU64,
    /// Replicas currently receiving new batches + autoscaler change counts.
    pub(crate) active: AtomicUsize,
    pub(crate) scale_ups: AtomicU64,
    pub(crate) scale_downs: AtomicU64,
    pub(crate) latency: Mutex<LatencyStats>,
    pub(crate) queue_latency: Mutex<LatencyStats>,
    pub(crate) batch_hist: Mutex<BatchHistogram>,
    pub(crate) replicas: Vec<ReplicaShared>,
    pub(crate) classes: Vec<ClassShared>,
}

impl Shared {
    pub(crate) fn new(replica_names: Vec<String>, max_batch: usize) -> Shared {
        Shared::with_classes(replica_names, max_batch, &SloClass::default_table())
    }

    pub(crate) fn with_classes(
        replica_names: Vec<String>,
        max_batch: usize,
        classes: &[SloClass],
    ) -> Shared {
        let n_replicas = replica_names.len();
        Shared {
            started: Instant::now(),
            uptime_frozen_us: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            deadline_rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_frames: AtomicU64::new(0),
            queue_p99_recent_us: AtomicU64::new(0),
            exec_ewma_us: AtomicU64::new(0),
            active: AtomicUsize::new(n_replicas),
            scale_ups: AtomicU64::new(0),
            scale_downs: AtomicU64::new(0),
            latency: Mutex::new(LatencyStats::default()),
            queue_latency: Mutex::new(LatencyStats::default()),
            batch_hist: Mutex::new(BatchHistogram::new(max_batch)),
            replicas: replica_names
                .into_iter()
                .map(|name| ReplicaShared {
                    name,
                    batches: AtomicU64::new(0),
                    frames: AtomicU64::new(0),
                    busy_us: AtomicU64::new(0),
                })
                .collect(),
            classes: classes.iter().map(ClassShared::new).collect(),
        }
    }

    /// Freeze the occupancy denominator at the current uptime. First call
    /// wins; snapshots taken any time later use the frozen value, so a
    /// post-shutdown snapshot equals the at-shutdown one instead of
    /// silently decaying toward zero as wall-clock time keeps passing.
    pub(crate) fn freeze_uptime(&self) {
        let now = self.started.elapsed().as_micros().max(1) as u64;
        let _ = self.uptime_frozen_us.compare_exchange(0, now, Ordering::Relaxed, Ordering::Relaxed);
    }

    /// Record one request's execution time into the admission EWMA
    /// (α = 1/8; racy read-modify-write is fine for a smoothing signal).
    pub(crate) fn record_exec_ewma(&self, exec_us: u64) {
        let prev = self.exec_ewma_us.load(Ordering::Relaxed);
        let next = if prev == 0 { exec_us.max(1) } else { (prev * 7 + exec_us) / 8 };
        self.exec_ewma_us.store(next.max(1), Ordering::Relaxed);
    }

    /// Total latency the admission check predicts for a request submitted
    /// now: recent queue p99 plus twice the execution EWMA (a queued
    /// request waits for the in-flight batch, then its own). Zero until
    /// both signals exist — cold starts admit everything.
    pub(crate) fn predicted_total_us(&self) -> u64 {
        let q = self.queue_p99_recent_us.load(Ordering::Relaxed);
        let e = self.exec_ewma_us.load(Ordering::Relaxed);
        q + 2 * e
    }

    pub(crate) fn snapshot(&self) -> StatsSnapshot {
        let latency = self.latency.lock().unwrap();
        let queue = self.queue_latency.lock().unwrap();
        let frozen = self.uptime_frozen_us.load(Ordering::Relaxed);
        let uptime_us = if frozen > 0 {
            frozen
        } else {
            self.started.elapsed().as_micros().max(1) as u64
        };
        let recent = self.queue_p99_recent_us.load(Ordering::Relaxed);
        StatsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            deadline_rejected: self.deadline_rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_frames: self.batched_frames.load(Ordering::Relaxed),
            p50_us: latency.percentile(50.0),
            p99_us: latency.percentile(99.0),
            mean_us: latency.mean(),
            queue_p50_us: queue.percentile(50.0),
            queue_p99_us: queue.percentile(99.0),
            queue_p99_recent_us: if recent > 0 { Some(recent) } else { None },
            queue_samples: queue.count() as u64,
            batch_hist: self.batch_hist.lock().unwrap().counts().to_vec(),
            active_replicas: self.active.load(Ordering::Relaxed) as u64,
            scale_ups: self.scale_ups.load(Ordering::Relaxed),
            scale_downs: self.scale_downs.load(Ordering::Relaxed),
            replicas: self
                .replicas
                .iter()
                .map(|r| {
                    let busy_us = r.busy_us.load(Ordering::Relaxed);
                    ReplicaStats {
                        name: r.name.clone(),
                        batches: r.batches.load(Ordering::Relaxed),
                        frames: r.frames.load(Ordering::Relaxed),
                        busy_us,
                        occupancy: busy_us as f64 / uptime_us as f64,
                    }
                })
                .collect(),
            classes: self
                .classes
                .iter()
                .map(|c| {
                    let lat = c.latency.lock().unwrap();
                    ClassStats {
                        name: c.name.clone(),
                        deadline_us: c.deadline_us,
                        submitted: c.submitted.load(Ordering::Relaxed),
                        completed: c.completed.load(Ordering::Relaxed),
                        shed_overload: c.shed_overload.load(Ordering::Relaxed),
                        shed_deadline: c.shed_deadline.load(Ordering::Relaxed),
                        p50_us: lat.percentile(50.0),
                        p99_us: lat.percentile(99.0),
                    }
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn occupancy_is_frozen_at_shutdown() {
        let shared = Shared::new(vec!["r0".into()], 4);
        shared.replicas[0].busy_us.store(10_000, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(20));
        shared.freeze_uptime();
        let at_shutdown = shared.snapshot();
        assert!(at_shutdown.replicas[0].occupancy > 0.0);
        // Regression: post-shutdown wall time must not dilute occupancy.
        std::thread::sleep(Duration::from_millis(40));
        let later = shared.snapshot();
        assert_eq!(
            later.replicas[0].occupancy, at_shutdown.replicas[0].occupancy,
            "occupancy decayed after shutdown"
        );
        // First freeze wins: a second freeze is a no-op.
        shared.freeze_uptime();
        assert_eq!(shared.snapshot().replicas[0].occupancy, at_shutdown.replicas[0].occupancy);
    }

    #[test]
    fn occupancy_decays_while_running() {
        // Sanity check of the inverse: without a freeze, the denominator
        // is live (this is the behaviour snapshots during a run rely on).
        let shared = Shared::new(vec!["r0".into()], 4);
        shared.replicas[0].busy_us.store(10_000, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(10));
        let a = shared.snapshot().replicas[0].occupancy;
        std::thread::sleep(Duration::from_millis(30));
        let b = shared.snapshot().replicas[0].occupancy;
        assert!(b < a, "live occupancy should decay while idle: {a} vs {b}");
    }

    #[test]
    fn export_metrics_is_idempotent_for_the_batch_histogram() {
        let snap = StatsSnapshot {
            batches: 6,
            batch_hist: vec![3, 0, 2, 1],
            ..Default::default()
        };
        let reg = crate::obs::Registry::new();
        snap.export_metrics(&reg);
        let h = reg.histogram("flow_serve_batch_size", "", &[]);
        assert_eq!(h.count(), 6);
        // Regression: repeated export must not double-count.
        snap.export_metrics(&reg);
        snap.export_metrics(&reg);
        assert_eq!(h.count(), 6, "repeated export double-counted the histogram");
        // A *grown* histogram imports only the delta.
        let grown = StatsSnapshot {
            batches: 8,
            batch_hist: vec![4, 0, 2, 2],
            ..Default::default()
        };
        grown.export_metrics(&reg);
        assert_eq!(h.count(), 8);
        assert_eq!(h.bucket_counts()[0], 4);
        assert_eq!(h.bucket_counts()[3], 2);
    }

    #[test]
    fn class_stats_helpers() {
        let c = ClassStats {
            deadline_us: Some(10_000),
            submitted: 80,
            completed: 80,
            shed_overload: 15,
            shed_deadline: 5,
            p99_us: Some(9_000),
            ..Default::default()
        };
        assert_eq!(c.shed_total(), 20);
        assert!((c.shed_rate() - 0.2).abs() < 1e-9);
        assert!(c.slo_met());
        let missed = ClassStats { deadline_us: Some(1_000), p99_us: Some(2_000), ..Default::default() };
        assert!(!missed.slo_met());
        let best_effort = ClassStats { p99_us: Some(1_000_000), ..Default::default() };
        assert!(best_effort.slo_met());
    }

    #[test]
    fn predicted_total_combines_signals_and_cold_start_is_zero() {
        let shared = Shared::new(vec![], 1);
        assert_eq!(shared.predicted_total_us(), 0);
        shared.queue_p99_recent_us.store(5_000, Ordering::Relaxed);
        shared.record_exec_ewma(1_000);
        assert_eq!(shared.predicted_total_us(), 5_000 + 2 * 1_000);
        // EWMA smooths: a spike moves the estimate 1/8 of the way.
        shared.record_exec_ewma(9_000);
        assert_eq!(shared.exec_ewma_us.load(Ordering::Relaxed), 2_000);
    }
}
