//! Inference coordinator: the paper's "host program" (§II-B) grown into a
//! dynamic-batching replica scheduler.
//!
//! ```text
//!  infer()/infer_async()          dispatcher thread        replica workers
//!  ──────────────────▶ BatchQueue ───────────────▶ ReplicaSet ─▶ [r0: Engine]
//!       │   bounded; coalesces to   pops batches;   weighted     [r1: Engine]
//!       │   max_batch or max_wait   records queue    round-      [r2: Engine]
//!       ▼                           latency          robin
//!  Err(Overloaded) when full                      (weight ∝ modeled FPS)
//! ```
//!
//! OpenCL-host concepts map directly onto the serving layer:
//!
//! * command queue → one replica worker owning its own engine; several
//!   replicas = concurrent execution (CE, §IV-G), one = serialized;
//! * dynamic batching → the [`BatchQueue`] coalesces single frames into
//!   device-native batches, amortizing per-dispatch overhead (the serving
//!   analog of autorun, §IV-F): flush at `max_batch` frames or after the
//!   oldest frame has waited `max_wait`, whichever comes first;
//! * multi-FPGA deployment (§VII) → the replica set may mix engines
//!   compiled for *different* registry targets (a
//!   [`crate::flow::multi::ReplicaPlan`]), with batches sharded
//!   proportionally to each replica's modeled throughput;
//! * kernel-launch overhead → per-dispatch cost in the engine model.
//!
//! Replicas execute through an [`Engine`]: [`PjrtEngine`] runs the
//! AOT-lowered artifacts on the PJRT runtime, [`SimEngine`] runs the
//! compiled accelerator's performance model — so the scheduler is
//! exercised end-to-end (tests, benches, `fpga-flow serve`) even where
//! artifacts or the PJRT bindings are absent.
//!
//! Data parallelism (replicas) is one multi-FPGA shape; the other is
//! *pipeline* parallelism, where a [`crate::flow::multi::PipelinePlan`]
//! splits one network across devices and [`PipelineServer`] runs one
//! stage worker per device, chained by bounded channels.
//!
//! Backpressure is explicit: the queue is bounded and a full queue fails
//! submissions with [`ServerError::Overloaded`] instead of buffering
//! without limit. Every *accepted* request is answered — shutdown drains
//! the queue, a failed engine answers with [`ServerError::Engine`] — so
//! the final [`StatsSnapshot`] always satisfies `completed == submitted`.

mod batcher;
mod engine;
mod pipeline;
mod replica;
mod stats;

pub use batcher::{BatchQueue, PushError};
pub use engine::{Engine, EngineSpec, PjrtEngine, SimEngine};
pub use pipeline::{export_pipeline_metrics, PipelineConfig, PipelineServer, StageSpec};
pub use stats::{ReplicaStats, StatsSnapshot};

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::runtime::{Impl, Manifest};

use replica::ReplicaSet;
use stats::Shared;

/// Typed serving failures. Wrapped in `anyhow::Error` by the public API;
/// `err.downcast_ref::<ServerError>()` recovers the variant (the same
/// pattern as [`crate::flow::CompileError`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServerError {
    /// The bounded request queue is full — shed load or retry later.
    Overloaded { capacity: usize },
    /// The server is shutting down (or its replicas are all gone).
    Stopped,
    /// The requested network is not in the artifacts manifest.
    UnknownNetwork { network: String },
    /// A submitted frame has the wrong number of elements.
    BadFrame { expected: usize, got: usize },
    /// The replica engine failed (failed to build, or execution error).
    Engine(String),
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::Overloaded { capacity } => {
                write!(f, "server overloaded: request queue at capacity ({capacity})")
            }
            ServerError::Stopped => write!(f, "server stopped"),
            ServerError::UnknownNetwork { network } => {
                write!(f, "network {network} not in the artifacts manifest")
            }
            ServerError::BadFrame { expected, got } => {
                write!(f, "bad frame: expected {expected} elements, got {got}")
            }
            ServerError::Engine(msg) => write!(f, "engine error: {msg}"),
        }
    }
}

impl std::error::Error for ServerError {}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Network name (used by the default PJRT replica fleet).
    pub network: String,
    /// Which functional path PJRT replicas execute.
    pub impl_: Impl,
    /// Number of identical PJRT replicas when [`ServerConfig::replicas`]
    /// is empty (the legacy "command queue" knob).
    pub workers: usize,
    /// Flush a batch at this many frames.
    pub max_batch: usize,
    /// Flush a partial batch once its oldest frame has waited this long.
    pub max_wait: Duration,
    /// Bound on queued frames; a full queue rejects with
    /// [`ServerError::Overloaded`].
    pub queue_capacity: usize,
    pub artifacts_dir: std::path::PathBuf,
    /// Explicit replica fleet (possibly heterogeneous). Empty = build
    /// `workers` PJRT replicas from `network`/`impl_`/`artifacts_dir`.
    pub replicas: Vec<EngineSpec>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            network: "lenet5".into(),
            impl_: Impl::Ref,
            workers: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_capacity: 1024,
            artifacts_dir: Manifest::default_dir(),
            replicas: Vec::new(),
        }
    }
}

/// One inference request travelling queue → dispatcher → replica.
pub(crate) struct Request {
    pub(crate) frame: Vec<f32>,
    pub(crate) submitted: Instant,
    /// When the dispatcher popped this request out of the queue — splits
    /// the lifecycle span into `queued` and `execute` at completion.
    pub(crate) dispatched: Option<Instant>,
    pub(crate) resp: Sender<crate::Result<u32>>,
}

/// A running inference server.
pub struct InferenceServer {
    queue: Arc<BatchQueue<Request>>,
    shared: Arc<Shared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl InferenceServer {
    /// Start the batcher, dispatcher and one worker per replica.
    ///
    /// With explicit [`ServerConfig::replicas`] the server runs on those
    /// engines (simulated fleets work anywhere); with none it builds
    /// `workers` identical PJRT replicas and fails fast when the artifacts
    /// or the network are missing.
    ///
    /// ```
    /// use std::time::Duration;
    /// use tvm_fpga_flow::coordinator::{EngineSpec, InferenceServer, ServerConfig, SimEngine};
    ///
    /// let replica = SimEngine::new("doc", 4, 10, 8, Duration::ZERO, Duration::ZERO);
    /// let server = InferenceServer::start(ServerConfig {
    ///     max_batch: 8,
    ///     max_wait: Duration::from_micros(200),
    ///     replicas: vec![EngineSpec::Sim(replica)],
    ///     ..Default::default()
    /// })
    /// .unwrap();
    /// assert!(server.infer(vec![0.5; 4]).unwrap() < 10);
    /// let stats = server.shutdown();
    /// assert_eq!(stats.completed, stats.submitted);
    /// ```
    pub fn start(cfg: ServerConfig) -> crate::Result<InferenceServer> {
        let specs: Vec<EngineSpec> = if cfg.replicas.is_empty() {
            // Legacy fleet: fail fast if artifacts are missing.
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            if manifest.network(&cfg.network).is_none() {
                return Err(ServerError::UnknownNetwork { network: cfg.network.clone() }.into());
            }
            (0..cfg.workers.max(1))
                .map(|_| EngineSpec::Pjrt {
                    artifacts_dir: cfg.artifacts_dir.clone(),
                    network: cfg.network.clone(),
                    impl_: cfg.impl_,
                    native_batch: cfg.max_batch.max(1),
                })
                .collect()
        } else {
            cfg.replicas.clone()
        };

        let names = specs.iter().enumerate().map(|(i, s)| format!("r{i}:{}", s.name())).collect();
        let shared = Arc::new(Shared::new(names, cfg.max_batch.max(1)));
        let queue = Arc::new(BatchQueue::new(
            cfg.queue_capacity,
            cfg.max_batch,
            cfg.max_wait,
        ));

        let (set, workers) = ReplicaSet::spawn(specs, &shared);

        let queue2 = Arc::clone(&queue);
        let shared2 = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("dispatcher".into())
            .spawn(move || dispatcher_loop(set, queue2, shared2))
            .expect("spawn dispatcher");

        Ok(InferenceServer { queue, shared, dispatcher: Some(dispatcher), workers })
    }

    /// Submit one frame; blocks until classified. Fails immediately with
    /// [`ServerError::Overloaded`] when the queue is full.
    pub fn infer(&self, frame: Vec<f32>) -> crate::Result<u32> {
        let rx = self.submit(frame)?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Submit asynchronously; returns the response channel.
    pub fn infer_async(&self, frame: Vec<f32>) -> crate::Result<Receiver<crate::Result<u32>>> {
        self.submit(frame)
    }

    /// Count the submission *before* enqueueing: a replica could otherwise
    /// complete it (bumping `completed`) before `submitted` is
    /// incremented, letting an observer see `completed > submitted`.
    /// Rejected pushes roll the count back and count as `rejected`.
    fn submit(&self, frame: Vec<f32>) -> crate::Result<Receiver<crate::Result<u32>>> {
        use std::sync::atomic::Ordering;
        let (tx, rx) = channel();
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        let req = Request { frame, submitted: Instant::now(), dispatched: None, resp: tx };
        match self.queue.push(req) {
            Ok(()) => {
                if crate::obs::enabled() {
                    crate::obs::global_metrics()
                        .counter("flow_serve_submitted_total", "requests accepted into the queue")
                        .inc();
                }
                Ok(rx)
            }
            Err(PushError::Full(_)) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed);
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                if crate::obs::enabled() {
                    crate::obs::global_metrics()
                        .counter("flow_serve_rejected_total", "requests shed by backpressure")
                        .inc();
                }
                Err(ServerError::Overloaded { capacity: self.queue.capacity() }.into())
            }
            Err(PushError::Closed(_)) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed);
                Err(ServerError::Stopped.into())
            }
        }
    }

    /// Live statistics (latency distributions, batch histogram,
    /// per-replica occupancy).
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.snapshot()
    }

    /// Frames currently queued (waiting for a batch slot).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Stop accepting work, drain the queue, join every thread, then
    /// snapshot. The snapshot must come *after* the joins: taking it first
    /// could under-count completions for batches still in flight. Closing
    /// the queue rejects new pushes while `pop_batch` keeps yielding the
    /// backlog, so every accepted submission is answered before the
    /// dispatcher exits and the final snapshot satisfies
    /// `completed == submitted` — even when a replica engine never came up
    /// (those requests complete with [`ServerError::Engine`]).
    pub fn shutdown(mut self) -> StatsSnapshot {
        self.queue.close();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shared.snapshot()
    }
}

impl Drop for InferenceServer {
    /// Close the queue so a dropped-without-`shutdown` server does not
    /// leave its dispatcher blocked forever (threads detach and drain).
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// Pop batches, record queue latency at dispatch, shard across replicas.
/// Exits (dropping the replica channels) once the queue is closed *and*
/// drained.
fn dispatcher_loop(mut set: ReplicaSet, queue: Arc<BatchQueue<Request>>, shared: Arc<Shared>) {
    while let Some(mut batch) = queue.pop_batch() {
        let now = Instant::now();
        {
            let mut ql = shared.queue_latency.lock().unwrap();
            for r in &mut batch {
                r.dispatched = Some(now);
                ql.record(now.saturating_duration_since(r.submitted).as_micros() as u64);
            }
        }
        set.dispatch(batch, &shared);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 2-replica simulated fleet with instant engines.
    fn sim_cfg(max_batch: usize, max_wait: Duration) -> ServerConfig {
        let eng = SimEngine::new("test", 16, 10, max_batch, Duration::ZERO, Duration::ZERO);
        ServerConfig {
            max_batch,
            max_wait,
            replicas: vec![EngineSpec::Sim(eng.clone()), EngineSpec::Sim(eng)],
            ..Default::default()
        }
    }

    #[test]
    fn sim_fleet_serves_and_batches() {
        let server = InferenceServer::start(sim_cfg(8, Duration::from_millis(5))).unwrap();
        let data = crate::data::mnist_like(32, 4, 9);
        let rxs: Vec<_> = (0..32)
            .map(|i| server.infer_async(data.frame(i).to_vec()).unwrap())
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().unwrap() < 10);
        }
        let stats = server.shutdown();
        assert_eq!(stats.submitted, 32);
        assert_eq!(stats.completed, stats.submitted, "{stats:?}");
        assert!(stats.p50_us.is_some());
        assert!(stats.queue_p50_us.is_some());
        // The burst must have produced at least one multi-frame batch,
        // visible in both the counter and the histogram.
        assert!(stats.batched_frames >= 2, "{stats:?}");
        assert!(stats.batch_hist.iter().skip(1).any(|&n| n > 0), "{stats:?}");
        assert_eq!(stats.replicas.len(), 2);
        assert_eq!(stats.replicas.iter().map(|r| r.frames).sum::<u64>(), 32);
    }

    #[test]
    fn max_batch_1_never_batches() {
        let server = InferenceServer::start(sim_cfg(1, Duration::from_millis(1))).unwrap();
        let data = crate::data::mnist_like(4, 4, 10);
        for i in 0..4 {
            assert!(server.infer(data.frame(i).to_vec()).unwrap() < 10);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.completed, stats.submitted);
        assert_eq!(stats.batched_frames, 0);
        assert_eq!(stats.batch_hist, vec![4]);
    }

    #[test]
    fn wrong_frame_size_is_typed_engine_error() {
        let server = InferenceServer::start(sim_cfg(4, Duration::from_millis(1))).unwrap();
        let err = server.infer(vec![0.0; 3]).unwrap_err();
        let se = err.downcast_ref::<ServerError>().expect("typed");
        assert!(matches!(se, ServerError::Engine(_)), "{se:?}");
        let stats = server.shutdown();
        // The failed request was still answered and counted.
        assert_eq!(stats.completed, stats.submitted);
    }

    #[test]
    fn broken_replica_answers_instead_of_abandoning() {
        // A PJRT replica with no artifacts can never build its engine; the
        // worker must answer with ServerError::Engine, not drop requests.
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            replicas: vec![EngineSpec::Pjrt {
                artifacts_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
                network: "lenet5".into(),
                impl_: Impl::Ref,
                native_batch: 4,
            }],
            ..Default::default()
        };
        let server = InferenceServer::start(cfg).unwrap();
        let err = server.infer(vec![0.0; 16]).unwrap_err();
        let se = err.downcast_ref::<ServerError>().expect("typed");
        assert!(matches!(se, ServerError::Engine(_)), "{se:?}");
        let stats = server.shutdown();
        assert_eq!(stats.completed, stats.submitted);
        assert_eq!(stats.completed, 1);
    }

    // ---- legacy artifact-gated coverage (skips without `make artifacts`
    // ---- or under the stubbed xla backend) -----------------------------

    fn artifacts_ready() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn pjrt_fleet_serves_requests_and_batches() {
        if !artifacts_ready() || !crate::runtime::backend_available() {
            eprintln!("skipping: needs `make artifacts` + the real xla bindings");
            return;
        }
        let server = InferenceServer::start(ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        })
        .unwrap();
        let data = crate::data::mnist_like(32, 32, 9);
        let rxs: Vec<_> = (0..32)
            .map(|i| server.infer_async(data.frame(i).to_vec()).unwrap())
            .collect();
        for rx in rxs {
            let pred = rx.recv().unwrap().unwrap();
            assert!(pred < 10);
        }
        let stats = server.shutdown();
        assert_eq!(stats.submitted, 32);
        assert_eq!(stats.completed, stats.submitted, "{stats:?}");
        assert!(stats.batched_frames >= 2, "{stats:?}");
    }

    #[test]
    fn bad_network_fails_fast() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let r = InferenceServer::start(ServerConfig {
            network: "vgg16".into(),
            ..Default::default()
        });
        assert!(r.is_err());
    }
}
