//! Inference coordinator: the paper's "host program" (§II-B) grown into a
//! dynamic-batching replica scheduler with SLO-class admission control.
//!
//! ```text
//!  infer()/infer_class()           dispatcher thread        replica workers
//!  ──────────────────▶ BatchQueue ───────────────▶ ReplicaSet ─▶ [r0: Engine]
//!       │   priority lanes; coalesces  pops batches;  weighted    [r1: Engine]
//!       │   to max_batch or max_wait   records queue   round-     [r2: Engine]
//!       ▼                              latency         robin
//!  Err(DeadlineUnmeetable) shed                     (weight ∝ modeled FPS,
//!  Err(Overloaded) when full                         first `active` only)
//! ```
//!
//! OpenCL-host concepts map directly onto the serving layer:
//!
//! * command queue → one replica worker owning its own engine; several
//!   replicas = concurrent execution (CE, §IV-G), one = serialized;
//! * dynamic batching → the [`BatchQueue`] coalesces single frames into
//!   device-native batches, amortizing per-dispatch overhead (the serving
//!   analog of autorun, §IV-F): flush at `max_batch` frames or after the
//!   oldest frame has waited `max_wait`, whichever comes first;
//! * multi-FPGA deployment (§VII) → the replica set may mix engines
//!   compiled for *different* registry targets (a
//!   [`crate::flow::multi::ReplicaPlan`]), with batches sharded
//!   proportionally to each replica's modeled throughput;
//! * kernel-launch overhead → per-dispatch cost in the engine model.
//!
//! Replicas execute through an [`Engine`]: [`PjrtEngine`] runs the
//! AOT-lowered artifacts on the PJRT runtime, [`SimEngine`] runs the
//! compiled accelerator's performance model — so the scheduler is
//! exercised end-to-end (tests, benches, `fpga-flow serve`) even where
//! artifacts or the PJRT bindings are absent.
//!
//! Data parallelism (replicas) is one multi-FPGA shape; the other is
//! *pipeline* parallelism, where a [`crate::flow::multi::PipelinePlan`]
//! splits one network across devices and [`PipelineServer`] runs one
//! stage worker per device, chained by bounded channels.
//!
//! Requests carry an [`SloClass`] (index = priority, 0 highest). Admission
//! control sheds *before* queueing: a request whose deadline the current
//! queue-latency percentiles cannot meet is refused at submission with
//! [`ServerError::DeadlineUnmeetable`] and never occupies a queue slot;
//! under full-queue pressure a higher-priority push evicts the youngest
//! lowest-priority queued request, which is answered
//! [`ServerError::Overloaded`] (shed-lowest-first). A [`ScalePolicy`]
//! (default [`HysteresisPolicy`]) can grow/shrink the *active* replica set
//! from the same queue-latency signal.
//!
//! Backpressure is explicit: the queue is bounded and a full queue fails
//! submissions with [`ServerError::Overloaded`] instead of buffering
//! without limit. Every *accepted* request is answered — shutdown drains
//! the queue, a failed engine answers with [`ServerError::Engine`] — so
//! the final [`StatsSnapshot`] always satisfies `completed == submitted`.

mod batcher;
mod engine;
pub mod loadgen;
mod pipeline;
mod replica;
mod scale;
pub mod slo;
mod stats;

pub use batcher::{BatchQueue, FlushCounts, FlushReason, PushError};
pub use engine::{Engine, EngineSpec, PjrtEngine, SimEngine};
pub use pipeline::{export_pipeline_metrics, PipelineConfig, PipelineServer, StageSpec};
pub use scale::{HysteresisPolicy, ScaleDecision, ScalePolicy};
pub use slo::SloClass;
pub use stats::{ClassStats, ReplicaStats, StatsSnapshot};

use std::sync::atomic::Ordering;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::runtime::{Impl, Manifest};

use replica::ReplicaSet;
use stats::Shared;

/// Typed serving failures. Wrapped in `anyhow::Error` by the public API;
/// `err.downcast_ref::<ServerError>()` recovers the variant (the same
/// pattern as [`crate::flow::CompileError`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServerError {
    /// The bounded request queue is full (or this request was evicted by a
    /// higher-priority one) — shed load or retry later.
    Overloaded { capacity: usize },
    /// Shed before queueing: the class deadline is smaller than the
    /// latency the current queue/execution signals predict.
    DeadlineUnmeetable { deadline_us: u64, predicted_us: u64 },
    /// The server is shutting down (or its replicas are all gone).
    Stopped,
    /// The requested network is not in the artifacts manifest.
    UnknownNetwork { network: String },
    /// A submitted frame has the wrong number of elements.
    BadFrame { expected: usize, got: usize },
    /// The replica engine failed (failed to build, or execution error).
    Engine(String),
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServerError::Overloaded { capacity } => {
                write!(f, "server overloaded: request queue at capacity ({capacity})")
            }
            ServerError::DeadlineUnmeetable { deadline_us, predicted_us } => write!(
                f,
                "deadline unmeetable: budget {deadline_us}us < predicted {predicted_us}us"
            ),
            ServerError::Stopped => write!(f, "server stopped"),
            ServerError::UnknownNetwork { network } => {
                write!(f, "network {network} not in the artifacts manifest")
            }
            ServerError::BadFrame { expected, got } => {
                write!(f, "bad frame: expected {expected} elements, got {got}")
            }
            ServerError::Engine(msg) => write!(f, "engine error: {msg}"),
        }
    }
}

impl std::error::Error for ServerError {}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Network name (used by the default PJRT replica fleet).
    pub network: String,
    /// Which functional path PJRT replicas execute.
    pub impl_: Impl,
    /// Number of identical PJRT replicas when [`ServerConfig::replicas`]
    /// is empty (the legacy "command queue" knob).
    pub workers: usize,
    /// Flush a batch at this many frames.
    pub max_batch: usize,
    /// Flush a partial batch once its oldest frame has waited this long.
    pub max_wait: Duration,
    /// Bound on queued frames; a full queue rejects with
    /// [`ServerError::Overloaded`].
    pub queue_capacity: usize,
    pub artifacts_dir: std::path::PathBuf,
    /// Explicit replica fleet (possibly heterogeneous). Empty = build
    /// `workers` PJRT replicas from `network`/`impl_`/`artifacts_dir`.
    pub replicas: Vec<EngineSpec>,
    /// SLO class table, highest priority first. Empty = a single
    /// best-effort class (every request behaves as before classes
    /// existed).
    pub classes: Vec<SloClass>,
    /// Autoscaling policy for the active replica count. `None` keeps the
    /// whole fleet active.
    pub autoscale: Option<HysteresisPolicy>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            network: "lenet5".into(),
            impl_: Impl::Ref,
            workers: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            queue_capacity: 1024,
            artifacts_dir: Manifest::default_dir(),
            replicas: Vec::new(),
            classes: Vec::new(),
            autoscale: None,
        }
    }
}

/// One inference request travelling queue → dispatcher → replica.
pub(crate) struct Request {
    pub(crate) frame: Vec<f32>,
    /// Index into the server's SLO class table (= priority).
    pub(crate) class: usize,
    pub(crate) submitted: Instant,
    /// When the dispatcher popped this request out of the queue — splits
    /// the lifecycle span into `queued` and `execute` at completion.
    pub(crate) dispatched: Option<Instant>,
    pub(crate) resp: Sender<crate::Result<u32>>,
}

/// A running inference server.
pub struct InferenceServer {
    queue: Arc<BatchQueue<Request>>,
    shared: Arc<Shared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl InferenceServer {
    /// Start the batcher, dispatcher and one worker per replica.
    ///
    /// With explicit [`ServerConfig::replicas`] the server runs on those
    /// engines (simulated fleets work anywhere); with none it builds
    /// `workers` identical PJRT replicas and fails fast when the artifacts
    /// or the network are missing.
    ///
    /// ```
    /// use std::time::Duration;
    /// use tvm_fpga_flow::coordinator::{EngineSpec, InferenceServer, ServerConfig, SimEngine};
    ///
    /// let replica = SimEngine::new("doc", 4, 10, 8, Duration::ZERO, Duration::ZERO);
    /// let server = InferenceServer::start(ServerConfig {
    ///     max_batch: 8,
    ///     max_wait: Duration::from_micros(200),
    ///     replicas: vec![EngineSpec::Sim(replica)],
    ///     ..Default::default()
    /// })
    /// .unwrap();
    /// assert!(server.infer(vec![0.5; 4]).unwrap() < 10);
    /// let stats = server.shutdown();
    /// assert_eq!(stats.completed, stats.submitted);
    /// ```
    pub fn start(cfg: ServerConfig) -> crate::Result<InferenceServer> {
        let policy = cfg.autoscale.clone().map(|p| Box::new(p) as Box<dyn ScalePolicy>);
        InferenceServer::start_inner(cfg, policy)
    }

    /// Start with a custom [`ScalePolicy`] (overrides
    /// [`ServerConfig::autoscale`]).
    pub fn start_with_policy(
        cfg: ServerConfig,
        policy: Box<dyn ScalePolicy>,
    ) -> crate::Result<InferenceServer> {
        InferenceServer::start_inner(cfg, Some(policy))
    }

    fn start_inner(
        cfg: ServerConfig,
        policy: Option<Box<dyn ScalePolicy>>,
    ) -> crate::Result<InferenceServer> {
        let specs: Vec<EngineSpec> = if cfg.replicas.is_empty() {
            // Legacy fleet: fail fast if artifacts are missing.
            let manifest = Manifest::load(&cfg.artifacts_dir)?;
            if manifest.network(&cfg.network).is_none() {
                return Err(ServerError::UnknownNetwork { network: cfg.network.clone() }.into());
            }
            (0..cfg.workers.max(1))
                .map(|_| EngineSpec::Pjrt {
                    artifacts_dir: cfg.artifacts_dir.clone(),
                    network: cfg.network.clone(),
                    impl_: cfg.impl_,
                    native_batch: cfg.max_batch.max(1),
                })
                .collect()
        } else {
            cfg.replicas.clone()
        };

        let classes =
            if cfg.classes.is_empty() { SloClass::default_table() } else { cfg.classes.clone() };
        let names = specs.iter().enumerate().map(|(i, s)| format!("r{i}:{}", s.name())).collect();
        let shared = Arc::new(Shared::with_classes(names, cfg.max_batch.max(1), &classes));
        let queue = Arc::new(BatchQueue::with_classes(
            cfg.queue_capacity,
            cfg.max_batch,
            cfg.max_wait,
            classes.len(),
        ));

        let (mut set, workers) = ReplicaSet::spawn(specs, &shared);
        if let Some(p) = &policy {
            set.set_active(p.initial(set.len()));
        }
        shared.active.store(set.active(), Ordering::Relaxed);

        let queue2 = Arc::clone(&queue);
        let shared2 = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("dispatcher".into())
            .spawn(move || dispatcher_loop(set, queue2, shared2, policy))
            .expect("spawn dispatcher");

        Ok(InferenceServer { queue, shared, dispatcher: Some(dispatcher), workers })
    }

    /// Submit one frame at the highest priority; blocks until classified.
    /// Fails immediately with [`ServerError::Overloaded`] when the queue
    /// is full.
    pub fn infer(&self, frame: Vec<f32>) -> crate::Result<u32> {
        self.infer_class(frame, 0)
    }

    /// Submit asynchronously at the highest priority.
    pub fn infer_async(&self, frame: Vec<f32>) -> crate::Result<Receiver<crate::Result<u32>>> {
        self.submit(frame, 0)
    }

    /// Submit one frame under the given SLO class (index into
    /// [`ServerConfig::classes`], clamped); blocks until classified.
    pub fn infer_class(&self, frame: Vec<f32>, class: usize) -> crate::Result<u32> {
        let rx = self.submit(frame, class)?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Submit asynchronously under the given SLO class; returns the
    /// response channel.
    pub fn infer_class_async(
        &self,
        frame: Vec<f32>,
        class: usize,
    ) -> crate::Result<Receiver<crate::Result<u32>>> {
        self.submit(frame, class)
    }

    /// Count the submission *before* enqueueing: a replica could otherwise
    /// complete it (bumping `completed`) before `submitted` is
    /// incremented, letting an observer see `completed > submitted`.
    /// Rejected pushes roll the count back and count as `rejected`.
    ///
    /// Admission control runs first, on atomics only: a deadline the
    /// current signals cannot meet is refused *before* the request touches
    /// the queue, so shed requests never record queue latency.
    fn submit(&self, frame: Vec<f32>, class: usize) -> crate::Result<Receiver<crate::Result<u32>>> {
        let class = class.min(self.shared.classes.len() - 1);
        let cs = &self.shared.classes[class];
        if let Some(deadline_us) = cs.deadline_us {
            let predicted_us = self.shared.predicted_total_us();
            if predicted_us > deadline_us {
                cs.shed_deadline.fetch_add(1, Ordering::Relaxed);
                self.shared.deadline_rejected.fetch_add(1, Ordering::Relaxed);
                if crate::obs::enabled() {
                    crate::obs::global_metrics()
                        .counter(
                            "flow_serve_deadline_rejected_total",
                            "requests shed before queueing (deadline unmeetable)",
                        )
                        .inc();
                }
                return Err(ServerError::DeadlineUnmeetable { deadline_us, predicted_us }.into());
            }
        }
        let (tx, rx) = channel();
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        cs.submitted.fetch_add(1, Ordering::Relaxed);
        let req = Request { frame, class, submitted: Instant::now(), dispatched: None, resp: tx };
        match self.queue.push_class(req, class) {
            Ok(victim) => {
                if let Some(v) = victim {
                    // A queued lower-priority request made way: it leaves
                    // `submitted` (it will never complete) and is answered
                    // Overloaded — shed-lowest-first under pressure.
                    self.shared.submitted.fetch_sub(1, Ordering::Relaxed);
                    self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                    let vcs = &self.shared.classes[v.class.min(self.shared.classes.len() - 1)];
                    vcs.submitted.fetch_sub(1, Ordering::Relaxed);
                    vcs.shed_overload.fetch_add(1, Ordering::Relaxed);
                    let _ = v.resp.send(Err(ServerError::Overloaded {
                        capacity: self.queue.capacity(),
                    }
                    .into()));
                    if crate::obs::enabled() {
                        crate::obs::global_metrics()
                            .counter("flow_serve_rejected_total", "requests shed by backpressure")
                            .inc();
                    }
                }
                if crate::obs::enabled() {
                    crate::obs::global_metrics()
                        .counter("flow_serve_submitted_total", "requests accepted into the queue")
                        .inc();
                }
                Ok(rx)
            }
            Err(PushError::Full(_)) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed);
                cs.submitted.fetch_sub(1, Ordering::Relaxed);
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                cs.shed_overload.fetch_add(1, Ordering::Relaxed);
                if crate::obs::enabled() {
                    crate::obs::global_metrics()
                        .counter("flow_serve_rejected_total", "requests shed by backpressure")
                        .inc();
                }
                Err(ServerError::Overloaded { capacity: self.queue.capacity() }.into())
            }
            Err(PushError::Closed(_)) => {
                self.shared.submitted.fetch_sub(1, Ordering::Relaxed);
                cs.submitted.fetch_sub(1, Ordering::Relaxed);
                Err(ServerError::Stopped.into())
            }
        }
    }

    /// Live statistics (latency distributions, batch histogram,
    /// per-replica occupancy, per-class SLO accounting).
    pub fn stats(&self) -> StatsSnapshot {
        self.shared.snapshot()
    }

    /// Frames currently queued (waiting for a batch slot).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Cumulative batch-flush counts by wake cause (size/deadline/close).
    pub fn flush_counts(&self) -> FlushCounts {
        self.queue.flush_counts()
    }

    /// Stop accepting work, drain the queue, join every thread, then
    /// snapshot. The snapshot must come *after* the joins: taking it first
    /// could under-count completions for batches still in flight. Closing
    /// the queue rejects new pushes while `pop_batch` keeps yielding the
    /// backlog, so every accepted submission is answered before the
    /// dispatcher exits and the final snapshot satisfies
    /// `completed == submitted` — even when a replica engine never came up
    /// (those requests complete with [`ServerError::Engine`]).
    ///
    /// The occupancy denominator freezes here: snapshots taken later (this
    /// one, or re-reads of a stored handle) keep reporting the occupancy
    /// at shutdown instead of decaying with wall-clock time.
    pub fn shutdown(mut self) -> StatsSnapshot {
        self.queue.close();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.shared.freeze_uptime();
        self.shared.snapshot()
    }
}

impl Drop for InferenceServer {
    /// Close the queue so a dropped-without-`shutdown` server does not
    /// leave its dispatcher blocked forever (threads detach and drain).
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// Pop batches, record queue latency at dispatch, shard across replicas.
/// Maintains the recent-window queue p99 the admission check reads, and
/// drives the autoscaling policy every few batches. Exits (dropping the
/// replica channels) once the queue is closed *and* drained.
fn dispatcher_loop(
    mut set: ReplicaSet,
    queue: Arc<BatchQueue<Request>>,
    shared: Arc<Shared>,
    mut policy: Option<Box<dyn ScalePolicy>>,
) {
    let mut batches_seen: u64 = 0;
    while let Some(mut batch) = queue.pop_batch() {
        let now = Instant::now();
        let recent = {
            let mut ql = shared.queue_latency.lock().unwrap();
            for r in &mut batch {
                r.dispatched = Some(now);
                ql.record(now.saturating_duration_since(r.submitted).as_micros() as u64);
            }
            ql.recent_percentile(stats::RECENT_WINDOW, 99.0)
        };
        if let Some(p) = recent {
            // max(1): zero is the "no signal yet" sentinel.
            shared.queue_p99_recent_us.store(p.max(1), Ordering::Relaxed);
        }
        set.dispatch(batch, &shared);
        batches_seen += 1;
        if batches_seen % 8 == 0 {
            if let Some(pol) = policy.as_mut() {
                let before = set.active();
                match pol.decide(before, &shared.snapshot()) {
                    ScaleDecision::Up(n) => {
                        set.set_active(before + n);
                        if set.active() != before {
                            shared.scale_ups.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    ScaleDecision::Down(n) => {
                        set.set_active(before.saturating_sub(n));
                        if set.active() != before {
                            shared.scale_downs.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    ScaleDecision::Hold => {}
                }
                shared.active.store(set.active(), Ordering::Relaxed);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A 2-replica simulated fleet with instant engines.
    fn sim_cfg(max_batch: usize, max_wait: Duration) -> ServerConfig {
        let eng = SimEngine::new("test", 16, 10, max_batch, Duration::ZERO, Duration::ZERO);
        ServerConfig {
            max_batch,
            max_wait,
            replicas: vec![EngineSpec::Sim(eng.clone()), EngineSpec::Sim(eng)],
            ..Default::default()
        }
    }

    #[test]
    fn sim_fleet_serves_and_batches() {
        let server = InferenceServer::start(sim_cfg(8, Duration::from_millis(5))).unwrap();
        let data = crate::data::mnist_like(32, 4, 9);
        let rxs: Vec<_> = (0..32)
            .map(|i| server.infer_async(data.frame(i).to_vec()).unwrap())
            .collect();
        for rx in rxs {
            assert!(rx.recv().unwrap().unwrap() < 10);
        }
        let stats = server.shutdown();
        assert_eq!(stats.submitted, 32);
        assert_eq!(stats.completed, stats.submitted, "{stats:?}");
        assert!(stats.p50_us.is_some());
        assert!(stats.queue_p50_us.is_some());
        // The burst must have produced at least one multi-frame batch,
        // visible in both the counter and the histogram.
        assert!(stats.batched_frames >= 2, "{stats:?}");
        assert!(stats.batch_hist.iter().skip(1).any(|&n| n > 0), "{stats:?}");
        assert_eq!(stats.replicas.len(), 2);
        assert_eq!(stats.replicas.iter().map(|r| r.frames).sum::<u64>(), 32);
        // Default class table: everything lands in one best-effort class.
        assert_eq!(stats.classes.len(), 1);
        assert_eq!(stats.classes[0].completed, 32);
        assert!(stats.classes[0].p99_us.is_some());
    }

    #[test]
    fn max_batch_1_never_batches() {
        let server = InferenceServer::start(sim_cfg(1, Duration::from_millis(1))).unwrap();
        let data = crate::data::mnist_like(4, 4, 10);
        for i in 0..4 {
            assert!(server.infer(data.frame(i).to_vec()).unwrap() < 10);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.completed, stats.submitted);
        assert_eq!(stats.batched_frames, 0);
        assert_eq!(stats.batch_hist, vec![4]);
    }

    #[test]
    fn wrong_frame_size_is_typed_engine_error() {
        let server = InferenceServer::start(sim_cfg(4, Duration::from_millis(1))).unwrap();
        let err = server.infer(vec![0.0; 3]).unwrap_err();
        let se = err.downcast_ref::<ServerError>().expect("typed");
        assert!(matches!(se, ServerError::Engine(_)), "{se:?}");
        let stats = server.shutdown();
        // The failed request was still answered and counted.
        assert_eq!(stats.completed, stats.submitted);
    }

    #[test]
    fn broken_replica_answers_instead_of_abandoning() {
        // A PJRT replica with no artifacts can never build its engine; the
        // worker must answer with ServerError::Engine, not drop requests.
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            replicas: vec![EngineSpec::Pjrt {
                artifacts_dir: std::path::PathBuf::from("/nonexistent/artifacts"),
                network: "lenet5".into(),
                impl_: Impl::Ref,
                native_batch: 4,
            }],
            ..Default::default()
        };
        let server = InferenceServer::start(cfg).unwrap();
        let err = server.infer(vec![0.0; 16]).unwrap_err();
        let se = err.downcast_ref::<ServerError>().expect("typed");
        assert!(matches!(se, ServerError::Engine(_)), "{se:?}");
        let stats = server.shutdown();
        assert_eq!(stats.completed, stats.submitted);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn class_stats_track_per_class_completions() {
        let mut cfg = sim_cfg(4, Duration::from_millis(1));
        cfg.classes = vec![
            SloClass::new("gold", Duration::from_secs(60)),
            SloClass::best_effort("bulk"),
        ];
        let server = InferenceServer::start(cfg).unwrap();
        let data = crate::data::mnist_like(8, 4, 9);
        for i in 0..8 {
            let class = i % 2;
            assert!(server.infer_class(data.frame(i).to_vec(), class).unwrap() < 10);
        }
        // Out-of-range class indices clamp to the lowest class.
        assert!(server.infer_class(data.frame(0).to_vec(), 99).unwrap() < 10);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 9);
        assert_eq!(stats.classes.len(), 2);
        assert_eq!(stats.classes[0].completed, 4);
        assert_eq!(stats.classes[1].completed, 5);
        assert!(stats.classes[0].slo_met());
        // Every dispatched request recorded queue latency, nothing else.
        assert_eq!(stats.queue_samples, stats.completed);
    }

    // ---- legacy artifact-gated coverage (skips without `make artifacts`
    // ---- or under the stubbed xla backend) -----------------------------

    fn artifacts_ready() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn pjrt_fleet_serves_requests_and_batches() {
        if !artifacts_ready() || !crate::runtime::backend_available() {
            eprintln!("skipping: needs `make artifacts` + the real xla bindings");
            return;
        }
        let server = InferenceServer::start(ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        })
        .unwrap();
        let data = crate::data::mnist_like(32, 32, 9);
        let rxs: Vec<_> = (0..32)
            .map(|i| server.infer_async(data.frame(i).to_vec()).unwrap())
            .collect();
        for rx in rxs {
            let pred = rx.recv().unwrap().unwrap();
            assert!(pred < 10);
        }
        let stats = server.shutdown();
        assert_eq!(stats.submitted, 32);
        assert_eq!(stats.completed, stats.submitted, "{stats:?}");
        assert!(stats.batched_frames >= 2, "{stats:?}");
    }

    #[test]
    fn bad_network_fails_fast() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let r = InferenceServer::start(ServerConfig {
            network: "vgg16".into(),
            ..Default::default()
        });
        assert!(r.is_err());
    }
}
