//! Inference coordinator: the "host program" of the paper's flow (§II-B)
//! grown into a serving component — request router, dynamic batcher and
//! command-queue workers over the PJRT runtime.
//!
//! OpenCL-host concepts map directly:
//! * command queue → one single-threaded worker owning a PJRT client;
//!   several workers = concurrent execution (CE, §IV-G), one = serialized;
//! * dynamic batching → the batched (`b16`) executable when the queue has
//!   enough pending frames, the `b1` executable otherwise;
//! * kernel-launch overhead → per-dispatch cost the batcher amortizes
//!   (the serving analog of autorun, §IV-F).
//!
//! Workers construct their own `Runtime` (PJRT client + weights) at spawn,
//! so nothing `!Send` crosses threads.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::LatencyStats;
use crate::runtime::{Impl, Manifest, Runtime};

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub network: String,
    pub impl_: Impl,
    /// Number of command-queue workers (1 = serialized, >1 = CE).
    pub workers: usize,
    /// Use the batched executable when this many frames are waiting.
    pub max_batch: usize,
    /// Max time the batcher waits to fill a batch.
    pub max_wait: Duration,
    pub artifacts_dir: std::path::PathBuf,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            network: "lenet5".into(),
            impl_: Impl::Ref,
            workers: 2,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            artifacts_dir: Manifest::default_dir(),
        }
    }
}

/// One inference request.
struct Request {
    frame: Vec<f32>,
    submitted: Instant,
    resp: Sender<crate::Result<u32>>,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Default)]
pub struct StatsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub batches: u64,
    pub batched_frames: u64,
    pub p50_us: Option<u64>,
    pub p99_us: Option<u64>,
    pub mean_us: Option<f64>,
}

struct Shared {
    latency: Mutex<LatencyStats>,
    submitted: AtomicU64,
    completed: AtomicU64,
    batches: AtomicU64,
    batched_frames: AtomicU64,
}

fn snapshot(shared: &Shared) -> StatsSnapshot {
    let lat = shared.latency.lock().unwrap();
    StatsSnapshot {
        submitted: shared.submitted.load(Ordering::Relaxed),
        completed: shared.completed.load(Ordering::Relaxed),
        batches: shared.batches.load(Ordering::Relaxed),
        batched_frames: shared.batched_frames.load(Ordering::Relaxed),
        p50_us: lat.percentile(50.0),
        p99_us: lat.percentile(99.0),
        mean_us: lat.mean(),
    }
}

/// A running inference server.
pub struct InferenceServer {
    req_tx: Sender<Request>,
    shared: Arc<Shared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl InferenceServer {
    /// Start the router + `cfg.workers` command-queue workers.
    pub fn start(cfg: ServerConfig) -> crate::Result<InferenceServer> {
        // Fail fast if artifacts are missing.
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        if manifest.network(&cfg.network).is_none() {
            anyhow::bail!("network {} not in artifacts", cfg.network);
        }

        let shared = Arc::new(Shared {
            latency: Mutex::new(LatencyStats::default()),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_frames: AtomicU64::new(0),
        });

        // Worker channels: each worker owns its Runtime (one "queue").
        let mut worker_txs: Vec<Sender<Vec<Request>>> = Vec::new();
        let mut workers = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let (tx, rx): (Sender<Vec<Request>>, Receiver<Vec<Request>>) = channel();
            worker_txs.push(tx);
            let cfg2 = cfg.clone();
            let shared2 = Arc::clone(&shared);
            workers.push(
                std::thread::Builder::new()
                    .name(format!("queue-{w}"))
                    .spawn(move || worker_loop(cfg2, shared2, rx))
                    .expect("spawn worker"),
            );
        }

        // Dispatcher: router + dynamic batcher.
        let (req_tx, req_rx) = channel::<Request>();
        let cfg2 = cfg.clone();
        let dispatcher = std::thread::Builder::new()
            .name("router".into())
            .spawn(move || dispatcher_loop(cfg2, req_rx, worker_txs))
            .expect("spawn dispatcher");

        Ok(InferenceServer { req_tx, shared, dispatcher: Some(dispatcher), workers })
    }

    /// Submit one frame; blocks until classified.
    pub fn infer(&self, frame: Vec<f32>) -> crate::Result<u32> {
        let rx = self.submit(frame)?;
        rx.recv().map_err(|_| anyhow::anyhow!("server dropped request"))?
    }

    /// Submit asynchronously; returns the response channel.
    pub fn infer_async(&self, frame: Vec<f32>) -> crate::Result<Receiver<crate::Result<u32>>> {
        self.submit(frame)
    }

    /// Count the submission *before* handing the request to the
    /// dispatcher: a worker could otherwise complete it (bumping
    /// `completed`) before `submitted` is incremented, letting an
    /// observer see `completed > submitted`.
    fn submit(&self, frame: Vec<f32>) -> crate::Result<Receiver<crate::Result<u32>>> {
        let (tx, rx) = channel();
        self.shared.submitted.fetch_add(1, Ordering::Relaxed);
        if self.req_tx.send(Request { frame, submitted: Instant::now(), resp: tx }).is_err() {
            self.shared.submitted.fetch_sub(1, Ordering::Relaxed);
            anyhow::bail!("server stopped");
        }
        Ok(rx)
    }

    pub fn stats(&self) -> StatsSnapshot {
        snapshot(&self.shared)
    }

    /// Stop accepting work and join all threads, then snapshot. The
    /// snapshot must come *after* the joins: taking it first could
    /// under-count completions for batches still in flight on the workers.
    /// While the workers are healthy, every accepted submission is
    /// drained before the dispatcher exits (mpsc reports disconnection
    /// only once its buffer is empty), so the final snapshot satisfies
    /// `completed == submitted`. A worker that died at startup (runtime
    /// init failure) abandons batches routed to it, and those
    /// submissions stay uncounted in `completed`.
    pub fn shutdown(mut self) -> StatsSnapshot {
        // Dropping req_tx disconnects the dispatcher once it has drained
        // the queue, which drops worker channels, which stops workers.
        drop(std::mem::replace(&mut self.req_tx, channel().0));
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        snapshot(&self.shared)
    }
}

fn dispatcher_loop(
    cfg: ServerConfig,
    req_rx: Receiver<Request>,
    worker_txs: Vec<Sender<Vec<Request>>>,
) {
    let mut next_worker = 0usize;
    loop {
        // Block for the first request. Exit only on disconnection, which
        // mpsc reports only after the queue is drained — shutdown must
        // never drop an accepted request.
        let first = match req_rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => r,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        };
        let mut batch = vec![first];
        // Dynamic batching: fill up to max_batch within max_wait. Blocking
        // recv_timeout instead of a try_recv+yield spin: on few-core hosts
        // the spin steals cycles from the PJRT workers (§Perf L3 log).
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            match req_rx.try_recv() {
                Ok(r) => {
                    batch.push(r);
                    continue;
                }
                Err(TryRecvError::Disconnected) => break,
                Err(TryRecvError::Empty) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match req_rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // Round-robin across command queues.
        let w = next_worker % worker_txs.len();
        next_worker = next_worker.wrapping_add(1);
        if worker_txs[w].send(batch).is_err() {
            break;
        }
    }
}

fn worker_loop(cfg: ServerConfig, shared: Arc<Shared>, rx: Receiver<Vec<Request>>) {
    // Each worker = one command queue with its own PJRT client.
    let rt = match Runtime::new(&cfg.artifacts_dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("worker: runtime init failed: {e}");
            return;
        }
    };
    let b1 = rt.load(&cfg.network, cfg.impl_, 1);
    let b16 = rt.load(&cfg.network, cfg.impl_, cfg.max_batch).ok();
    let b1 = match b1 {
        Ok(m) => m,
        Err(e) => {
            eprintln!("worker: load failed: {e}");
            return;
        }
    };
    let frame_elems = b1.frame_elems();

    while let Ok(batch) = rx.recv() {
        let use_batched = b16.as_ref().filter(|_| batch.len() > 1).is_some();
        if use_batched {
            let model = b16.as_ref().unwrap();
            // Pad to the executable's fixed batch with zero frames.
            let mut frames = vec![0f32; cfg.max_batch * frame_elems];
            for (i, r) in batch.iter().enumerate() {
                frames[i * frame_elems..(i + 1) * frame_elems].copy_from_slice(&r.frame);
            }
            let result = model.classify(&rt.client, &frames);
            shared.batches.fetch_add(1, Ordering::Relaxed);
            shared.batched_frames.fetch_add(batch.len() as u64, Ordering::Relaxed);
            match result {
                Ok(preds) => {
                    for (r, &p) in batch.iter().zip(&preds) {
                        finish(&shared, r, Ok(p));
                    }
                }
                Err(e) => {
                    for r in &batch {
                        finish(&shared, r, Err(anyhow::anyhow!("{e}")));
                    }
                }
            }
        } else {
            for r in &batch {
                let result = b1
                    .classify(&rt.client, &r.frame)
                    .map(|p| p.first().copied().unwrap_or(0));
                shared.batches.fetch_add(1, Ordering::Relaxed);
                finish(&shared, r, result);
            }
        }
    }
}

fn finish(shared: &Shared, req: &Request, result: crate::Result<u32>) {
    let us = req.submitted.elapsed().as_micros() as u64;
    shared.latency.lock().unwrap().record(us);
    shared.completed.fetch_add(1, Ordering::Relaxed);
    let _ = req.resp.send(result);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn serves_requests_and_batches() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let server = InferenceServer::start(ServerConfig {
            workers: 2,
            max_wait: Duration::from_millis(5),
            ..Default::default()
        })
        .unwrap();
        let data = crate::data::mnist_like(32, 32, 9);
        // Async burst to give the batcher something to coalesce.
        let rxs: Vec<_> = (0..32)
            .map(|i| server.infer_async(data.frame(i).to_vec()).unwrap())
            .collect();
        for rx in rxs {
            let pred = rx.recv().unwrap().unwrap();
            assert!(pred < 10);
        }
        let stats = server.shutdown();
        assert_eq!(stats.submitted, 32);
        // Joined-then-snapshotted: nothing submitted may be missing from
        // the completion count.
        assert_eq!(stats.completed, stats.submitted, "{stats:?}");
        assert!(stats.p50_us.is_some());
        // The burst must have produced at least one multi-frame batch.
        assert!(stats.batched_frames >= 2, "{stats:?}");
    }

    #[test]
    fn single_worker_serializes_like_one_queue() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let server = InferenceServer::start(ServerConfig {
            workers: 1,
            max_batch: 1,
            ..Default::default()
        })
        .unwrap();
        let data = crate::data::mnist_like(4, 32, 10);
        for i in 0..4 {
            assert!(server.infer(data.frame(i).to_vec()).unwrap() < 10);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 4);
        assert_eq!(stats.completed, stats.submitted);
        assert_eq!(stats.batched_frames, 0);
    }

    #[test]
    fn bad_network_fails_fast() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let r = InferenceServer::start(ServerConfig { network: "vgg16".into(), ..Default::default() });
        assert!(r.is_err());
    }
}
