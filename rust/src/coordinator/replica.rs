//! Replica workers and weighted routing.
//!
//! A [`ReplicaSet`] owns one worker thread per replica. Each worker builds
//! its own engine (PJRT clients are not shareable across threads) and
//! executes whole batches; the dispatcher shards batches across replicas
//! with smooth weighted round-robin, weights proportional to each
//! replica's modeled throughput — an `agilex7` replica modeled at 2× the
//! `arria10gx` FPS receives 2× the batches.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SendError, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::engine::{Engine, EngineSpec};
use super::stats::Shared;
use super::{Request, ServerError};

/// Smooth weighted round-robin (the nginx algorithm): deterministic, no
/// starvation, and interleaves picks instead of bursting — over any window
/// of `sum(weights)` picks each replica is chosen ~proportionally.
pub(crate) struct WeightedRouter {
    weights: Vec<f64>,
    current: Vec<f64>,
}

impl WeightedRouter {
    pub(crate) fn new(weights: Vec<f64>) -> WeightedRouter {
        let weights: Vec<f64> =
            weights.into_iter().map(|w| if w.is_finite() && w > 0.0 { w } else { 1e-9 }).collect();
        let current = vec![0.0; weights.len()];
        WeightedRouter { weights, current }
    }

    /// Index of the next replica to receive work (whole fleet active).
    #[cfg(test)]
    pub(crate) fn pick(&mut self) -> usize {
        self.pick_among(self.weights.len())
    }

    /// Weighted pick restricted to the first `n` replicas — the autoscaled
    /// *active* prefix of the fleet. Inactive replicas accumulate no
    /// credit, so re-activating one does not hand it a burst of back-pay.
    pub(crate) fn pick_among(&mut self, n: usize) -> usize {
        let n = n.clamp(1, self.weights.len());
        let total: f64 = self.weights[..n].iter().sum();
        let mut best = 0;
        for i in 0..n {
            self.current[i] += self.weights[i];
            if self.current[i] > self.current[best] {
                best = i;
            }
        }
        self.current[best] -= total;
        best
    }
}

/// The spawned replica fleet: per-replica *bounded* channels (one batch
/// executing + one staged per replica) plus the router. Bounded channels
/// matter: a saturated fleet blocks the dispatcher, the request queue
/// fills, and submitters see [`super::ServerError::Overloaded`] — the
/// backpressure path would be dead code if batches could buffer without
/// limit here. Dropping the set closes every channel, which is what tells
/// the workers to exit once they drain.
pub(crate) struct ReplicaSet {
    txs: Vec<SyncSender<Vec<Request>>>,
    router: WeightedRouter,
    /// New batches route only to replicas `0..active` — the autoscaler's
    /// knob. Deactivated replicas drain whatever they already hold.
    active: usize,
}

impl ReplicaSet {
    /// Spawn one worker per spec. Returns the set (for the dispatcher) and
    /// the join handles (for shutdown).
    pub(crate) fn spawn(
        specs: Vec<EngineSpec>,
        shared: &Arc<Shared>,
    ) -> (ReplicaSet, Vec<JoinHandle<()>>) {
        let router = WeightedRouter::new(specs.iter().map(|s| s.weight()).collect());
        let mut txs = Vec::with_capacity(specs.len());
        let mut handles = Vec::with_capacity(specs.len());
        for (i, spec) in specs.into_iter().enumerate() {
            let (tx, rx): (SyncSender<Vec<Request>>, Receiver<Vec<Request>>) = sync_channel(1);
            let shared = Arc::clone(shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("replica-{i}"))
                    .spawn(move || worker_loop(i, spec, shared, rx))
                    .expect("spawn replica worker"),
            );
            txs.push(tx);
        }
        let active = txs.len();
        (ReplicaSet { txs, router, active }, handles)
    }

    /// Spawned fleet size.
    pub(crate) fn len(&self) -> usize {
        self.txs.len()
    }

    /// Replicas currently receiving new batches.
    pub(crate) fn active(&self) -> usize {
        self.active
    }

    /// Set the active prefix, clamped to `[1, len]` — the fleet never
    /// scales to zero (a server with no sink would deadlock its queue).
    pub(crate) fn set_active(&mut self, n: usize) {
        self.active = n.clamp(1, self.txs.len().max(1));
    }

    /// Route one batch. The weighted pick gets first refusal; a busy
    /// replica overflows to the next free one (occupancy-aware routing),
    /// and when the whole fleet is busy the dispatcher *blocks* on the
    /// weighted pick — that stall is what propagates backpressure to the
    /// bounded request queue. Dead replicas (panicked workers) are
    /// skipped; if every replica is gone the batch is answered with
    /// [`ServerError::Stopped`] so no submission goes unanswered.
    pub(crate) fn dispatch(&mut self, mut batch: Vec<Request>, shared: &Shared) {
        let n = self.active.clamp(1, self.txs.len());
        let first = self.router.pick_among(n);
        for step in 0..n {
            match self.txs[(first + step) % n].try_send(batch) {
                Ok(()) => return,
                Err(TrySendError::Full(b)) | Err(TrySendError::Disconnected(b)) => batch = b,
            }
        }
        // Everyone busy (or dead): block on the weighted pick, falling
        // through to later replicas only if the pick's worker is gone.
        for step in 0..n {
            match self.txs[(first + step) % n].send(batch) {
                Ok(()) => return,
                Err(SendError(b)) => batch = b,
            }
        }
        for req in &batch {
            finish(shared, req, Err(ServerError::Stopped.into()));
        }
    }
}

/// One replica worker: build the engine, then execute batches until the
/// dispatcher hangs up. An engine that fails to build (e.g. PJRT
/// unavailable, artifacts missing a batch variant) answers every routed
/// request with a typed error instead of abandoning it — the
/// `completed == submitted` shutdown invariant holds even for a fleet
/// that never became healthy.
fn worker_loop(idx: usize, spec: EngineSpec, shared: Arc<Shared>, rx: Receiver<Vec<Request>>) {
    let engine: crate::Result<Box<dyn Engine>> = spec.build();
    while let Ok(batch) = rx.recv() {
        match &engine {
            Ok(engine) => execute_batch(idx, engine.as_ref(), &shared, &batch),
            Err(e) => {
                let msg = format!("replica engine unavailable: {e}");
                for req in &batch {
                    finish(&shared, req, Err(ServerError::Engine(msg.clone()).into()));
                }
            }
        }
    }
}

fn execute_batch(idx: usize, engine: &dyn Engine, shared: &Shared, batch: &[Request]) {
    let frames: Vec<&[f32]> = batch.iter().map(|r| r.frame.as_slice()).collect();
    let t0 = Instant::now();
    let result = engine.classify_batch(&frames);
    let busy_us = t0.elapsed().as_micros() as u64;
    if crate::obs::enabled() {
        crate::obs::span_at(
            "serve",
            "batch",
            None,
            t0,
            Instant::now(),
            vec![
                ("frames", crate::obs::ArgValue::Num(batch.len() as f64)),
                ("replica", crate::obs::ArgValue::Num(idx as f64)),
            ],
        );
        crate::obs::global_metrics()
            .counter("flow_serve_batches_total", "batches executed across all replicas")
            .inc();
    }

    let k = batch.len();
    shared.batches.fetch_add(1, Ordering::Relaxed);
    if k > 1 {
        shared.batched_frames.fetch_add(k as u64, Ordering::Relaxed);
    }
    shared.batch_hist.lock().unwrap().record(k);
    let rs = &shared.replicas[idx];
    rs.batches.fetch_add(1, Ordering::Relaxed);
    rs.frames.fetch_add(k as u64, Ordering::Relaxed);
    rs.busy_us.fetch_add(busy_us, Ordering::Relaxed);

    match result {
        Ok(preds) if preds.len() == k => {
            for (req, &p) in batch.iter().zip(&preds) {
                finish(shared, req, Ok(p));
            }
        }
        Ok(preds) => {
            let msg = format!("engine returned {} predictions for {k} frames", preds.len());
            for req in batch {
                finish(shared, req, Err(ServerError::Engine(msg.clone()).into()));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for req in batch {
                finish(shared, req, Err(ServerError::Engine(msg.clone()).into()));
            }
        }
    }
}

/// Record latency + completion and deliver the response. `completed`
/// counts every delivered response, errors included: it is the "nothing
/// was dropped" counter, not the success counter.
pub(crate) fn finish(shared: &Shared, req: &Request, result: crate::Result<u32>) {
    let done = Instant::now();
    let us = done.saturating_duration_since(req.submitted).as_micros() as u64;
    shared.latency.lock().unwrap().record(us);
    shared.completed.fetch_add(1, Ordering::Relaxed);
    if let Some(cs) = shared.classes.get(req.class.min(shared.classes.len().saturating_sub(1))) {
        cs.latency.lock().unwrap().record(us);
        cs.completed.fetch_add(1, Ordering::Relaxed);
    }
    if let Some(d) = req.dispatched {
        // Feed the admission predictor with dispatch→response time.
        shared.record_exec_ewma(done.saturating_duration_since(d).as_micros() as u64);
    }
    if crate::obs::enabled() {
        // The full lifecycle span tree, reconstructed post-hoc:
        // `request` (submit → response) with `queued` (submit → dispatch)
        // and `execute` (dispatch → response) children.
        let id = crate::obs::span_at(
            "serve",
            "request",
            None,
            req.submitted,
            done,
            vec![("ok", crate::obs::ArgValue::Bool(result.is_ok()))],
        );
        if let Some(d) = req.dispatched {
            crate::obs::span_at("serve", "queued", id, req.submitted, d, vec![]);
            crate::obs::span_at("serve", "execute", id, d, done, vec![]);
        }
        crate::obs::global_metrics()
            .counter("flow_serve_completed_total", "responses delivered (successes and errors)")
            .inc();
    }
    let _ = req.resp.send(result);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wrr_is_proportional() {
        let mut r = WeightedRouter::new(vec![3.0, 1.0]);
        let picks: Vec<usize> = (0..8).map(|_| r.pick()).collect();
        assert_eq!(picks.iter().filter(|&&p| p == 0).count(), 6);
        assert_eq!(picks.iter().filter(|&&p| p == 1).count(), 2);
        // Smooth: the heavy replica must not get all its turns in a burst.
        assert_ne!(picks[..4].iter().filter(|&&p| p == 1).count(), 0);
    }

    #[test]
    fn wrr_uniform_weights_round_robin() {
        let mut r = WeightedRouter::new(vec![1.0, 1.0, 1.0]);
        let picks: Vec<usize> = (0..6).map(|_| r.pick()).collect();
        for i in 0..3 {
            assert_eq!(picks.iter().filter(|&&p| p == i).count(), 2, "{picks:?}");
        }
    }

    #[test]
    fn wrr_pick_among_restricts_to_active_prefix() {
        let mut r = WeightedRouter::new(vec![1.0, 1.0, 4.0]);
        // Only the first two replicas are active: the heavy third one must
        // never be picked, and the first two alternate.
        let picks: Vec<usize> = (0..6).map(|_| r.pick_among(2)).collect();
        assert!(picks.iter().all(|&p| p < 2), "{picks:?}");
        assert_eq!(picks.iter().filter(|&&p| p == 0).count(), 3, "{picks:?}");
        // Growing back to the full fleet re-admits the heavy replica.
        let picks: Vec<usize> = (0..12).map(|_| r.pick_among(3)).collect();
        assert!(picks.iter().filter(|&&p| p == 2).count() >= 6, "{picks:?}");
    }

    #[test]
    fn wrr_survives_degenerate_weights() {
        let mut r = WeightedRouter::new(vec![0.0, f64::NAN, -3.0]);
        for _ in 0..9 {
            let p = r.pick();
            assert!(p < 3);
        }
    }
}
