//! Replica autoscaling: scale-up/down decisions driven by the
//! queue-latency percentiles [`StatsSnapshot`] already reports.
//!
//! The whole replica fleet is spawned up front (worker threads are cheap;
//! the modeled or real accelerators behind them are not re-synthesized by
//! scaling), and the dispatcher routes only to the first `active`
//! replicas. Scaling a replica "up" therefore means *activating* an
//! already-spawned worker, and scaling "down" stops routing new batches
//! to it — in-flight work drains normally, so no accepted request is ever
//! dropped by a scale-down.
//!
//! Decisions are a policy: the dispatcher periodically feeds the current
//! [`StatsSnapshot`] to a [`ScalePolicy`] and applies the returned
//! [`ScaleDecision`]. [`HysteresisPolicy`] is the default implementation:
//! separate up/down thresholds on the recent queue-latency p99 plus a
//! cooldown, so a fleet near a single threshold does not flap.

use super::StatsSnapshot;

/// What the policy wants done with the active replica count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Keep the current active count.
    Hold,
    /// Activate up to `n` more replicas (clamped to the spawned fleet).
    Up(usize),
    /// Deactivate up to `n` replicas (clamped to a minimum of one).
    Down(usize),
}

/// A scale-up/down policy. The dispatcher calls [`ScalePolicy::decide`]
/// periodically (every few batches) with the live snapshot; implementors
/// own any internal state (cooldowns, trend windows).
pub trait ScalePolicy: Send {
    /// How many replicas to activate at server start, given the spawned
    /// fleet size. Defaults to the whole fleet.
    fn initial(&self, spawned: usize) -> usize {
        spawned
    }

    /// Decide from the current active count and a fresh snapshot.
    fn decide(&mut self, active: usize, snap: &StatsSnapshot) -> ScaleDecision;
}

/// Default policy: hysteresis on the recent queue-latency p99.
///
/// Scales up one replica when the recent queue p99 exceeds
/// `scale_up_queue_us`, down one when it falls below
/// `scale_down_queue_us`, and holds for `cooldown` decisions after any
/// change. The two thresholds plus the cooldown are the anti-flap
/// hysteresis band; keep `scale_down_queue_us` well below
/// `scale_up_queue_us`.
///
/// ```
/// use tvm_fpga_flow::coordinator::{HysteresisPolicy, ScaleDecision, ScalePolicy, StatsSnapshot};
///
/// let mut p = HysteresisPolicy::new(1, 4, 10_000, 500);
/// let hot = StatsSnapshot { queue_p99_recent_us: Some(25_000), ..Default::default() };
/// assert_eq!(p.decide(1, &hot), ScaleDecision::Up(1));
/// ```
#[derive(Debug, Clone)]
pub struct HysteresisPolicy {
    /// Never deactivate below this many replicas.
    pub min_replicas: usize,
    /// Never activate more than this many replicas.
    pub max_replicas: usize,
    /// Recent queue p99 above this scales up.
    pub scale_up_queue_us: u64,
    /// Recent queue p99 below this scales down.
    pub scale_down_queue_us: u64,
    /// Decisions to hold after any scale change (anti-flap).
    pub cooldown: u32,
    cooldown_left: u32,
}

impl HysteresisPolicy {
    /// A policy between `min`/`max` active replicas with the given
    /// up/down thresholds (µs of recent queue p99) and a 4-decision
    /// cooldown.
    pub fn new(min: usize, max: usize, up_us: u64, down_us: u64) -> HysteresisPolicy {
        HysteresisPolicy {
            min_replicas: min.max(1),
            max_replicas: max.max(min.max(1)),
            scale_up_queue_us: up_us,
            scale_down_queue_us: down_us.min(up_us),
            cooldown: 4,
            cooldown_left: 0,
        }
    }

    /// Override the anti-flap cooldown (in decisions).
    pub fn with_cooldown(mut self, decisions: u32) -> HysteresisPolicy {
        self.cooldown = decisions;
        self
    }
}

impl ScalePolicy for HysteresisPolicy {
    fn initial(&self, spawned: usize) -> usize {
        self.min_replicas.clamp(1, spawned)
    }

    fn decide(&mut self, active: usize, snap: &StatsSnapshot) -> ScaleDecision {
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return ScaleDecision::Hold;
        }
        // Prefer the recent-window percentile: the run-cumulative p99
        // never decays after a burst, which would pin the fleet at max.
        let p99 = match snap.queue_p99_recent_us.or(snap.queue_p99_us) {
            Some(p) => p,
            None => return ScaleDecision::Hold,
        };
        if p99 > self.scale_up_queue_us && active < self.max_replicas {
            self.cooldown_left = self.cooldown;
            ScaleDecision::Up(1)
        } else if p99 < self.scale_down_queue_us && active > self.min_replicas {
            self.cooldown_left = self.cooldown;
            ScaleDecision::Down(1)
        } else {
            ScaleDecision::Hold
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(p99_recent: Option<u64>) -> StatsSnapshot {
        StatsSnapshot { queue_p99_recent_us: p99_recent, ..Default::default() }
    }

    #[test]
    fn scales_up_on_hot_queue_and_respects_max() {
        let mut p = HysteresisPolicy::new(1, 2, 10_000, 500).with_cooldown(0);
        assert_eq!(p.decide(1, &snap(Some(50_000))), ScaleDecision::Up(1));
        // At max, a hot queue holds instead of overshooting.
        assert_eq!(p.decide(2, &snap(Some(50_000))), ScaleDecision::Hold);
    }

    #[test]
    fn scales_down_on_cold_queue_and_respects_min() {
        let mut p = HysteresisPolicy::new(1, 4, 10_000, 500).with_cooldown(0);
        assert_eq!(p.decide(3, &snap(Some(100))), ScaleDecision::Down(1));
        assert_eq!(p.decide(1, &snap(Some(100))), ScaleDecision::Hold);
    }

    #[test]
    fn hysteresis_band_and_cooldown_prevent_flapping() {
        let mut p = HysteresisPolicy::new(1, 4, 10_000, 500).with_cooldown(2);
        // In the band between the thresholds: hold.
        assert_eq!(p.decide(2, &snap(Some(5_000))), ScaleDecision::Hold);
        // A change arms the cooldown; the next two decisions hold even
        // though the signal is still hot.
        assert_eq!(p.decide(2, &snap(Some(50_000))), ScaleDecision::Up(1));
        assert_eq!(p.decide(3, &snap(Some(50_000))), ScaleDecision::Hold);
        assert_eq!(p.decide(3, &snap(Some(50_000))), ScaleDecision::Hold);
        assert_eq!(p.decide(3, &snap(Some(50_000))), ScaleDecision::Up(1));
    }

    #[test]
    fn no_signal_holds() {
        let mut p = HysteresisPolicy::new(1, 4, 10_000, 500).with_cooldown(0);
        assert_eq!(p.decide(2, &snap(None)), ScaleDecision::Hold);
    }

    #[test]
    fn initial_active_is_min() {
        let p = HysteresisPolicy::new(2, 8, 10_000, 500);
        assert_eq!(p.initial(4), 2);
        assert_eq!(p.initial(1), 1);
    }
}
