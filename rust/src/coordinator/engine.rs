//! Execution engines behind the replica workers.
//!
//! A replica is "something that classifies a batch of frames": either the
//! PJRT runtime executing the AOT-lowered networks ([`PjrtEngine`]), or a
//! modeled accelerator ([`SimEngine`]) whose timing comes from the staged
//! compile flow's performance report. The scheduler, batcher and stats are
//! identical over both, so serving behaviour (batch coalescing, weighted
//! routing, backpressure) is testable without artifacts or a PJRT build.
//!
//! [`SimEngine`] timing model: each dispatch pays the accelerator's
//! *host-side* share of the frame time once per batch (that is the §IV-F
//! dispatch overhead batching amortizes), while the *device* share is paid
//! per frame (a pipelined datapath accepts one frame per initiation
//! interval regardless of how they were submitted).

use std::path::PathBuf;
use std::time::Duration;

use crate::flow::multi::ReplicaPlan;
use crate::flow::Accelerator;
use crate::graph::Graph;
use crate::runtime::{Impl, LoadedModel, Runtime};

use super::ServerError;

/// A batch executor owned by one replica worker thread. (Identity and
/// routing weight live on [`EngineSpec`], which exists before the engine
/// is built; engines only need to answer shape queries and execute.)
pub trait Engine: Send {
    /// Elements of one input frame.
    fn frame_elems(&self) -> usize;

    /// Classes in the output layer.
    fn num_classes(&self) -> usize;

    /// Classify every frame; one prediction per input frame, in order.
    /// Batches larger than the engine's device-native batch are chunked
    /// internally.
    fn classify_batch(&self, frames: &[&[f32]]) -> crate::Result<Vec<u32>>;
}

/// How a replica worker constructs its engine.
///
/// Construction is deferred to the worker thread on purpose: the real
/// PJRT client is not `Send`, so each worker builds (and exclusively owns)
/// its own runtime — the same reason the pre-replica coordinator created
/// one `Runtime` per command-queue worker.
#[derive(Debug, Clone)]
pub enum EngineSpec {
    /// A modeled accelerator; ready-made, cheap to clone.
    Sim(SimEngine),
    /// Load artifacts and run through the PJRT runtime.
    Pjrt { artifacts_dir: PathBuf, network: String, impl_: Impl, native_batch: usize },
}

impl EngineSpec {
    /// Routing weight before the engine exists (modeled FPS for sim
    /// replicas; PJRT replicas are assumed homogeneous).
    pub fn weight(&self) -> f64 {
        match self {
            EngineSpec::Sim(e) => e.modeled_fps().max(f64::MIN_POSITIVE),
            EngineSpec::Pjrt { .. } => 1.0,
        }
    }

    /// Stable replica name for stats.
    pub fn name(&self) -> String {
        match self {
            EngineSpec::Sim(e) => e.name().to_string(),
            EngineSpec::Pjrt { network, impl_, .. } => format!("{network}@pjrt/{}", impl_.tag()),
        }
    }

    /// Build the engine (called on the owning worker thread).
    pub fn build(self) -> crate::Result<Box<dyn Engine>> {
        match self {
            EngineSpec::Sim(e) => Ok(Box::new(e)),
            EngineSpec::Pjrt { artifacts_dir, network, impl_, native_batch } => Ok(Box::new(
                PjrtEngine::load(&artifacts_dir, &network, impl_, native_batch)?,
            )),
        }
    }
}

/// A modeled accelerator replica: timing from the compiled design's
/// performance report, predictions from a deterministic content hash.
///
/// ```
/// use std::time::Duration;
/// use tvm_fpga_flow::coordinator::SimEngine;
/// use tvm_fpga_flow::coordinator::Engine;
///
/// let eng = SimEngine::new("demo", 4, 10, 8, Duration::ZERO, Duration::ZERO);
/// let a = [0.0f32, 1.0, 2.0, 3.0];
/// let b = [9.0f32, 8.0, 7.0, 6.0];
/// let preds = eng.classify_batch(&[&a, &b]).unwrap();
/// assert_eq!(preds.len(), 2);
/// assert!(preds.iter().all(|&p| p < 10));
/// // Same frames, same predictions — the engine is deterministic.
/// assert_eq!(preds, eng.classify_batch(&[&a, &b]).unwrap());
/// ```
#[derive(Debug, Clone)]
pub struct SimEngine {
    name: String,
    frame_elems: usize,
    num_classes: usize,
    native_batch: usize,
    /// Paid once per dispatch (the host share batching amortizes).
    dispatch_overhead: Duration,
    /// Paid once per frame (the device share).
    frame_time: Duration,
    /// Failure-injection hooks for chaos tests (off in normal engines).
    chaos: Option<Chaos>,
}

/// Chaos knobs for failure-injection tests. Deliberately invisible to
/// [`SimEngine::modeled_fps`]: the routing weight keeps advertising the
/// healthy throughput, so the scheduler has to *discover* the degradation
/// through backpressure rather than being told about it.
#[derive(Debug, Clone)]
struct Chaos {
    /// Panic the worker thread once this many frames have executed
    /// (`None` = never die).
    kill_after_frames: Option<usize>,
    /// Multiply the modeled execution time (1.0 = healthy).
    slowdown: f64,
    /// Frames executed so far (shared across engine clones).
    served: std::sync::Arc<std::sync::atomic::AtomicUsize>,
}

impl SimEngine {
    /// An engine with explicit timing constants (benches, tests, demos).
    pub fn new(
        name: impl Into<String>,
        frame_elems: usize,
        num_classes: usize,
        native_batch: usize,
        dispatch_overhead: Duration,
        frame_time: Duration,
    ) -> SimEngine {
        SimEngine {
            name: name.into(),
            frame_elems: frame_elems.max(1),
            num_classes: num_classes.max(1),
            native_batch: native_batch.max(1),
            dispatch_overhead,
            frame_time,
            chaos: None,
        }
    }

    /// Derive an engine from a compiled accelerator: the performance
    /// report's host fraction becomes the per-dispatch overhead, the rest
    /// of the frame time is paid per frame.
    pub fn from_accelerator(
        name: impl Into<String>,
        acc: &Accelerator,
        graph: &Graph,
        native_batch: usize,
    ) -> SimEngine {
        let frame_s = acc.performance.frame_time_s.max(0.0);
        let host_frac = acc.performance.host_frac.clamp(0.0, 1.0);
        SimEngine::new(
            name,
            graph.nodes[graph.input].shape.elems(),
            graph.nodes[graph.output].shape.elems(),
            native_batch,
            Duration::from_secs_f64(frame_s * host_frac),
            Duration::from_secs_f64(frame_s * (1.0 - host_frac)),
        )
    }

    /// One engine per [`ReplicaPlan`] entry, named `network@target` (with
    /// a `:precision` suffix for quantized accelerators, so fleet stats
    /// distinguish an int8 replica from its fp32 sibling).
    pub fn from_plan(
        plan: &ReplicaPlan,
        graph: &Graph,
        native_batch: usize,
    ) -> crate::Result<Vec<SimEngine>> {
        anyhow::ensure!(
            plan.network == graph.name,
            "replica plan is for {} but the graph is {}",
            plan.network,
            graph.name
        );
        Ok(plan
            .entries
            .iter()
            .map(|e| {
                let name = if e.accelerator.precision == crate::texpr::Precision::F32 {
                    format!("{}@{}", plan.network, e.target.name)
                } else {
                    format!("{}@{}:{}", plan.network, e.target.name, e.accelerator.precision)
                };
                SimEngine::from_accelerator(name, &e.accelerator, graph, native_batch)
            })
            .collect())
    }

    /// Failure injection: the worker thread running this engine panics
    /// once `frames` frames have executed — a replica crash mid-run. The
    /// routing weight is unaffected (the fleet finds out the hard way).
    pub fn with_chaos_kill_after(mut self, frames: usize) -> SimEngine {
        let c = self.chaos.get_or_insert_with(Chaos::default);
        c.kill_after_frames = Some(frames);
        self
    }

    /// Failure injection: execution silently runs `factor`× slower than
    /// the model the routing weight advertises — a hidden straggler.
    pub fn with_chaos_slowdown(mut self, factor: f64) -> SimEngine {
        let c = self.chaos.get_or_insert_with(Chaos::default);
        c.slowdown = if factor.is_finite() && factor > 0.0 { factor } else { 1.0 };
        self
    }

    /// Compress (scale > 1) or stretch modeled time, e.g. to keep demo
    /// runs of slow networks short. Predictions are unaffected.
    pub fn with_time_scale(mut self, scale: f64) -> SimEngine {
        let s = if scale.is_finite() && scale > 0.0 { scale } else { 1.0 };
        self.dispatch_overhead = Duration::from_secs_f64(self.dispatch_overhead.as_secs_f64() / s);
        self.frame_time = Duration::from_secs_f64(self.frame_time.as_secs_f64() / s);
        self
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Modeled steady-state throughput at full native batches — the
    /// replica's routing weight.
    pub fn modeled_fps(&self) -> f64 {
        let n = self.native_batch as f64;
        let batch_s = self.dispatch_overhead.as_secs_f64() + n * self.frame_time.as_secs_f64();
        n / batch_s.max(1e-12)
    }
}

impl Default for Chaos {
    fn default() -> Chaos {
        Chaos {
            kill_after_frames: None,
            slowdown: 1.0,
            served: std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0)),
        }
    }
}

/// Deterministic per-frame "prediction": FNV-1a over the f32 bit patterns.
/// Shared with the pipeline server so a partitioned deployment answers
/// exactly what a whole-network [`SimEngine`] would.
pub(crate) fn hash_predict(frame: &[f32], classes: usize) -> u32 {
    let mut h = crate::util::FNV_OFFSET;
    for v in frame {
        h = crate::util::fnv64_with(h, &v.to_bits().to_le_bytes());
    }
    (h % classes.max(1) as u64) as u32
}

impl Engine for SimEngine {
    fn frame_elems(&self) -> usize {
        self.frame_elems
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn classify_batch(&self, frames: &[&[f32]]) -> crate::Result<Vec<u32>> {
        for f in frames {
            if f.len() != self.frame_elems {
                return Err(ServerError::BadFrame {
                    expected: self.frame_elems,
                    got: f.len(),
                }
                .into());
            }
        }
        let k = frames.len();
        let mut span = crate::obs::span("engine", &self.name);
        span.set_arg("frames", k);
        if k > 0 {
            let dispatches = k.div_ceil(self.native_batch) as u32;
            span.set_arg("dispatches", dispatches as u64);
            let mut busy = self.dispatch_overhead * dispatches + self.frame_time * k as u32;
            if let Some(c) = &self.chaos {
                if c.slowdown != 1.0 {
                    busy = Duration::from_secs_f64(busy.as_secs_f64() * c.slowdown);
                }
            }
            if busy > Duration::ZERO {
                std::thread::sleep(busy);
            }
        }
        if let Some(c) = &self.chaos {
            let before = c.served.fetch_add(k, std::sync::atomic::Ordering::Relaxed);
            if let Some(limit) = c.kill_after_frames {
                if before + k > limit {
                    // Take the worker thread down mid-batch: in-flight
                    // requests are dropped (their response senders die
                    // with this stack), and the replica channel
                    // disconnects so routing sweeps past the corpse.
                    panic!("chaos: replica {} killed after {limit} frames", self.name);
                }
            }
        }
        Ok(frames.iter().map(|f| hash_predict(f, self.num_classes)).collect())
    }
}

/// The PJRT-backed replica: a `batch=1` executable for stragglers plus the
/// device-native batched executable, with padding handled by
/// [`LoadedModel::classify_padded`].
pub struct PjrtEngine {
    rt: Runtime,
    b1: LoadedModel,
    bn: Option<LoadedModel>,
}

impl PjrtEngine {
    /// Load the runtime and executables for one replica.
    pub fn load(
        artifacts_dir: &std::path::Path,
        network: &str,
        impl_: Impl,
        native_batch: usize,
    ) -> crate::Result<PjrtEngine> {
        let rt = Runtime::new(artifacts_dir)?;
        let b1 = rt.load(network, impl_, 1)?;
        let bn = (native_batch > 1).then(|| rt.load(network, impl_, native_batch).ok()).flatten();
        Ok(PjrtEngine { rt, b1, bn })
    }
}

impl Engine for PjrtEngine {
    fn frame_elems(&self) -> usize {
        self.b1.frame_elems()
    }

    fn num_classes(&self) -> usize {
        self.b1.num_classes
    }

    fn classify_batch(&self, frames: &[&[f32]]) -> crate::Result<Vec<u32>> {
        let elems = self.frame_elems();
        for f in frames {
            if f.len() != elems {
                return Err(ServerError::BadFrame { expected: elems, got: f.len() }.into());
            }
        }
        let mut preds = Vec::with_capacity(frames.len());
        match &self.bn {
            // Multi-frame work goes through the batched executable in
            // native-sized chunks, padded by the runtime.
            Some(bn) if frames.len() > 1 => {
                for chunk in frames.chunks(bn.batch) {
                    let mut flat = Vec::with_capacity(chunk.len() * elems);
                    for f in chunk {
                        flat.extend_from_slice(f);
                    }
                    preds.extend(bn.classify_padded(&self.rt.client, &flat, chunk.len())?);
                }
            }
            _ => {
                for f in frames {
                    let p = self.b1.classify(&self.rt.client, f)?;
                    preds.push(p.first().copied().unwrap_or(0));
                }
            }
        }
        Ok(preds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn sim_engine_validates_frame_size() {
        let eng = SimEngine::new("t", 4, 10, 8, Duration::ZERO, Duration::ZERO);
        let bad = [0.0f32; 3];
        let err = eng.classify_batch(&[&bad]).unwrap_err();
        let se = err.downcast_ref::<ServerError>().expect("typed ServerError");
        assert_eq!(*se, ServerError::BadFrame { expected: 4, got: 3 });
    }

    #[test]
    fn sim_engine_predictions_spread_across_classes() {
        let eng = SimEngine::new("t", 16, 10, 8, Duration::ZERO, Duration::ZERO);
        let data = crate::data::mnist_like(32, 4, 3);
        let frames: Vec<&[f32]> = (0..32).map(|i| data.frame(i)).collect();
        let preds = eng.classify_batch(&frames).unwrap();
        assert!(preds.iter().all(|&p| p < 10));
        let distinct: std::collections::BTreeSet<_> = preds.iter().collect();
        assert!(distinct.len() >= 3, "degenerate hash predictions: {preds:?}");
    }

    #[test]
    fn from_plan_names_and_shapes_follow_targets() {
        let g = models::lenet5();
        let plan = ReplicaPlan::build(&g, &["stratix10sx", "agilex7"]).unwrap();
        let engines = SimEngine::from_plan(&plan, &g, 8).unwrap();
        assert_eq!(engines.len(), 2);
        assert_eq!(engines[0].name(), "lenet5@stratix10sx");
        assert_eq!(engines[1].name(), "lenet5@agilex7");
        for e in &engines {
            assert_eq!(e.frame_elems(), 32 * 32);
            assert_eq!(e.num_classes(), 10);
            assert!(e.modeled_fps() > 0.0);
        }
    }

    #[test]
    fn quantized_plan_suffixes_replica_names() {
        let g = models::lenet5();
        let f32_plan = ReplicaPlan::build(&g, &["stratix10sx"]).unwrap();
        let i8_plan = ReplicaPlan::build_with(
            &g,
            &["stratix10sx"],
            Some(crate::quant::QuantConfig::int8()),
        )
        .unwrap();
        let f = SimEngine::from_plan(&f32_plan, &g, 8).unwrap();
        let q = SimEngine::from_plan(&i8_plan, &g, 8).unwrap();
        assert_eq!(f[0].name(), "lenet5@stratix10sx");
        assert_eq!(q[0].name(), "lenet5@stratix10sx:int8");
        // The int8 accelerator is never modeled slower than its fp32
        // sibling, so routing weights stay sane in mixed fleets.
        assert!(q[0].modeled_fps() >= f[0].modeled_fps() * 0.99);
        assert_eq!(q[0].frame_elems(), 32 * 32);
        assert_eq!(q[0].num_classes(), 10);
    }

    #[test]
    fn chaos_kill_panics_after_threshold_and_hides_from_weight() {
        let eng = SimEngine::new("t", 4, 10, 8, Duration::ZERO, Duration::ZERO)
            .with_chaos_kill_after(2);
        let healthy = SimEngine::new("t", 4, 10, 8, Duration::ZERO, Duration::ZERO);
        // Chaos must not leak into the routing weight.
        assert_eq!(eng.modeled_fps(), healthy.modeled_fps());
        let f = [0.0f32; 4];
        assert_eq!(eng.classify_batch(&[&f, &f]).unwrap().len(), 2);
        let boom = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = eng.classify_batch(&[&f]);
        }));
        assert!(boom.is_err(), "third frame must cross the kill threshold");
    }

    #[test]
    fn chaos_slowdown_is_invisible_to_the_model() {
        let eng = SimEngine::new(
            "t",
            4,
            10,
            8,
            Duration::ZERO,
            Duration::from_micros(200),
        );
        let slow = eng.clone().with_chaos_slowdown(20.0);
        assert_eq!(slow.modeled_fps(), eng.modeled_fps());
        let f = [0.0f32; 4];
        let t0 = std::time::Instant::now();
        slow.classify_batch(&[&f]).unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(3), "{:?}", t0.elapsed());
    }

    #[test]
    fn time_scale_speeds_up_the_model() {
        let eng = SimEngine::new(
            "t",
            4,
            10,
            8,
            Duration::from_millis(10),
            Duration::from_millis(1),
        );
        let fast = eng.clone().with_time_scale(10.0);
        assert!(fast.modeled_fps() > eng.modeled_fps() * 5.0);
        // Degenerate scales fall back to identity rather than panicking.
        let same = eng.clone().with_time_scale(0.0);
        assert!((same.modeled_fps() - eng.modeled_fps()).abs() < 1e-6);
    }
}
