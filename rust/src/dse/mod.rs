//! Design-space explorer over unroll/tile factors — the automation the
//! paper leaves to future work (§IV-J: "we manually sweep through several
//! parameter values … Ideally, a design space explorer (DSE) can be
//! developed to automate this process").
//!
//! The explorer sweeps candidate (t_ic, t_oc) tiles per parameterized
//! group (folded) or per-kernel unroll caps (pipelined), applies the three
//! §IV-J legality rules through the staged flow, and keeps the best
//! simulated-FPS design. Candidate tiles are ordered diagonal-first
//! (balanced tiles from small to large, then increasingly skewed pairs) so
//! a small budget still samples the whole magnitude range instead of only
//! the lexicographically-first corner of the grid.
//!
//! Because many candidate tiles clamp to the same effective factors (rule
//! 2 divisibility) the sweep revisits identical kernel programs; the
//! [`Compiler`]'s synthesis memo turns those into cache hits, reported in
//! [`DseResult::synth_cache`].

use crate::flow::{patterns::FactorPlan, CacheStats, Compiler, Mode, OptConfig};
use crate::graph::{Graph, ParamGroup};

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub plan: FactorPlan,
    pub fps: f64,
    pub fmax_mhz: f64,
    pub dsp_frac: f64,
    pub logic_frac: f64,
    pub bram_frac: f64,
    /// None = synthesized; Some(reason) = rejected.
    pub rejected: Option<String>,
}

/// Exploration result: best design + full log.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub best: Option<DsePoint>,
    pub log: Vec<DsePoint>,
    pub evaluated: usize,
    /// Synthesis-memo hits/misses attributable to this exploration.
    pub synth_cache: CacheStats,
}

impl DseResult {
    /// Fraction of synthesis requests served from the memo during the
    /// sweep.
    pub fn synth_cache_hit_rate(&self) -> f64 {
        self.synth_cache.hit_rate()
    }
}

/// Candidate per-dimension tile factors (powers of two are router-friendly
/// and divide the evaluation networks' channel counts).
pub const TILE_CANDIDATES: [u64; 7] = [1, 2, 4, 8, 16, 32, 64];

/// The full (t_ic, t_oc) candidate grid, ordered diagonal-first: balanced
/// pairs from small to large, then pairs of growing imbalance. Truncating
/// this order to any budget keeps coverage of the whole magnitude range —
/// the previous lexicographic `truncate` never reached tiles ≥ 16 for any
/// realistic budget.
pub fn tile_candidates_ordered() -> Vec<(u64, u64)> {
    let n = TILE_CANDIDATES.len();
    let mut idx: Vec<(usize, usize)> = Vec::with_capacity(n * n);
    for i in 0..n {
        for j in 0..n {
            idx.push((i, j));
        }
    }
    idx.sort_by_key(|&(i, j)| (i.abs_diff(j), i + j, i));
    idx.into_iter().map(|(i, j)| (TILE_CANDIDATES[i], TILE_CANDIDATES[j])).collect()
}

/// Sweep folded-mode tiles for every parameterized group, one group at a
/// time (coordinate descent: groups are resource-coupled but the paper's
/// manual sweep treats them independently too).
pub fn explore_folded(compiler: &Compiler, graph: &Graph, budget_per_group: usize) -> DseResult {
    let cache_before = compiler.cache_stats();
    let base_plan = crate::flow::default_factors(graph);
    let groups: Vec<ParamGroup> = base_plan.group_tiles.keys().copied().collect();

    let mut best_plan = base_plan.clone();
    let mut log = Vec::new();
    let mut evaluated = 0;
    let mut best_fps = eval(compiler, graph, Mode::Folded, &best_plan, &mut log, &mut evaluated);

    let mut candidates = tile_candidates_ordered();
    candidates.truncate(budget_per_group.max(1));

    for g in &groups {
        for &(t_ic, t_oc) in &candidates {
            let mut plan = best_plan.clone();
            plan.group_tiles.insert(*g, (t_ic, t_oc));
            let fps = eval(compiler, graph, Mode::Folded, &plan, &mut log, &mut evaluated);
            if fps > best_fps {
                best_fps = fps;
                best_plan = plan;
            }
        }
    }

    finish(log, evaluated, compiler, cache_before)
}

/// Sweep pipelined unroll caps.
pub fn explore_pipelined(compiler: &Compiler, graph: &Graph) -> DseResult {
    let cache_before = compiler.cache_stats();
    let mut log = Vec::new();
    let mut evaluated = 0;
    for cap in [16u64, 32, 64, 128, 256, 512, 1024] {
        let mut plan = crate::flow::default_factors(graph);
        plan.pipelined_cap = cap;
        eval(compiler, graph, Mode::Pipelined, &plan, &mut log, &mut evaluated);
    }
    finish(log, evaluated, compiler, cache_before)
}

fn finish(
    log: Vec<DsePoint>,
    evaluated: usize,
    compiler: &Compiler,
    cache_before: CacheStats,
) -> DseResult {
    let best = log
        .iter()
        .filter(|p| p.rejected.is_none())
        .max_by(|a, b| a.fps.total_cmp(&b.fps))
        .cloned();
    let after = compiler.cache_stats();
    let synth_cache = CacheStats {
        hits: after.hits - cache_before.hits,
        misses: after.misses - cache_before.misses,
    };
    DseResult { best, log, evaluated, synth_cache }
}

fn eval(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    plan: &FactorPlan,
    log: &mut Vec<DsePoint>,
    evaluated: &mut usize,
) -> f64 {
    *evaluated += 1;
    match eval_point(compiler, graph, mode, plan) {
        Ok(p) => {
            let fps = p.fps;
            log.push(p);
            fps
        }
        Err(e) => {
            log.push(DsePoint {
                plan: plan.clone(),
                fps: 0.0,
                fmax_mhz: 0.0,
                dsp_frac: 0.0,
                logic_frac: 0.0,
                bram_frac: 0.0,
                rejected: Some(e.to_string()),
            });
            0.0
        }
    }
}

/// Evaluate one design point through the staged API: the explorer only
/// needs the synthesis report and the performance numbers, so no per-point
/// `Accelerator` (with its kernel-program deep copy) is materialized.
fn eval_point(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    plan: &FactorPlan,
) -> crate::Result<DsePoint> {
    let mut session =
        compiler.graph(graph).mode(mode).opts(OptConfig::optimized()).plan(plan.clone());
    session.lower()?;
    let design = session.synthesize()?;
    let u = design.synthesis.resources.utilization;
    let perf = design.performance();
    Ok(DsePoint {
        plan: plan.clone(),
        fps: perf.fps,
        fmax_mhz: design.synthesis.fmax_mhz,
        dsp_frac: u.dsp_frac,
        logic_frac: u.logic_frac,
        bram_frac: u.bram_frac,
        rejected: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::graph::GroupKind;

    #[test]
    fn pipelined_dse_finds_a_design() {
        let compiler = Compiler::default();
        let r = explore_pipelined(&compiler, &models::lenet5());
        let best = r.best.expect("some design routes");
        assert!(best.fps > 1000.0);
        assert!(r.evaluated >= 7);
    }

    #[test]
    fn folded_dse_improves_or_matches_default() {
        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let default_fps = compiler
            .compile(&g, Mode::Folded, crate::flow::OptLevel::Optimized)
            .unwrap()
            .performance
            .fps;
        let r = explore_folded(&compiler, &g, 12);
        let best = r.best.expect("best exists");
        assert!(best.fps >= default_fps * 0.99, "dse {} vs default {}", best.fps, default_fps);
    }

    #[test]
    fn dse_log_contains_rejections_for_huge_tiles() {
        // Force an oversized sweep on ResNet: 64×64 tiles on the 3×3 group
        // would be 36K lanes — must be rejected (rule 3 / routing).
        let compiler = Compiler::default();
        let g = models::resnet34();
        let mut plan = crate::flow::default_factors(&g);
        for (_, t) in plan.group_tiles.iter_mut() {
            *t = (64, 64);
        }
        let mut log = Vec::new();
        let mut n = 0;
        let fps = eval(&compiler, &g, Mode::Folded, &plan, &mut log, &mut n);
        assert_eq!(fps, 0.0);
        assert!(log[0].rejected.is_some());
    }

    #[test]
    fn candidate_order_is_diagonal_first_and_complete() {
        let c = tile_candidates_ordered();
        assert_eq!(c.len(), TILE_CANDIDATES.len() * TILE_CANDIDATES.len());
        // Balanced tiles lead, small to large.
        assert_eq!(&c[..7], &[(1, 1), (2, 2), (4, 4), (8, 8), (16, 16), (32, 32), (64, 64)]);
        // No duplicates.
        let mut seen = std::collections::BTreeSet::new();
        assert!(c.iter().all(|t| seen.insert(*t)));
    }

    #[test]
    fn budget_12_still_evaluates_large_tiles() {
        // Regression for the old `candidates.truncate(budget)` bug, which
        // kept only the lexicographically-first (all-small) tile pairs: a
        // budget of 12 must still evaluate at least one tile ≥ 16 for the
        // swept groups — checked on a depthwise group whose default tile
        // is (8, 1), so any ≥16 entry can only come from the sweep.
        let first12 = tile_candidates_ordered().into_iter().take(12).collect::<Vec<_>>();
        assert!(first12.iter().any(|&(a, b)| a.max(b) >= 16), "{first12:?}");

        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let r = explore_folded(&compiler, &g, 12);
        let dw = ParamGroup { kind: GroupKind::Depthwise, kernel: 3, stride: 1 };
        assert!(
            r.log.iter().any(|p| p
                .plan
                .group_tiles
                .get(&dw)
                .is_some_and(|&(a, b)| a.max(b) >= 16)),
            "no large depthwise tile was ever evaluated under budget 12"
        );
    }

    #[test]
    fn folded_dse_reports_synthesis_cache_hits() {
        // Depthwise groups ignore t_oc and small extents clamp large
        // tiles, so the sweep necessarily revisits identical programs —
        // the memo must convert those into hits.
        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let r = explore_folded(&compiler, &g, 16);
        assert!(r.synth_cache.hits > 0, "{:?}", r.synth_cache);
        assert!(r.synth_cache_hit_rate() > 0.0);
        assert!(r.synth_cache.total() <= r.evaluated as u64);
    }
}
