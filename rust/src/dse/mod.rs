//! Design-space explorer over unroll/tile factors — the automation the
//! paper leaves to future work (§IV-J: "we manually sweep through several
//! parameter values … Ideally, a design space explorer (DSE) can be
//! developed to automate this process").
//!
//! The explorer sweeps candidate (t_ic, t_oc) tiles per parameterized
//! group (folded) or per-kernel unroll caps (pipelined), applies the three
//! §IV-J legality rules through the normal flow, and keeps the best
//! simulated-FPS design. Because our "synthesis" is a model, a full sweep
//! takes milliseconds where the paper's Quartus runs took 3–12 hours per
//! point.

use crate::flow::{patterns::FactorPlan, Flow, Mode, OptConfig};
use crate::graph::{Graph, ParamGroup};

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub plan: FactorPlan,
    pub fps: f64,
    pub fmax_mhz: f64,
    pub dsp_frac: f64,
    pub logic_frac: f64,
    pub bram_frac: f64,
    /// None = synthesized; Some(reason) = rejected.
    pub rejected: Option<String>,
}

/// Exploration result: best design + full log.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub best: Option<DsePoint>,
    pub log: Vec<DsePoint>,
    pub evaluated: usize,
}

/// Candidate per-dimension tile factors (powers of two are router-friendly
/// and divide the evaluation networks' channel counts).
pub const TILE_CANDIDATES: [u64; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Sweep folded-mode tiles for every parameterized group, one group at a
/// time (coordinate descent: groups are resource-coupled but the paper's
/// manual sweep treats them independently too).
pub fn explore_folded(flow: &Flow, graph: &Graph, budget_per_group: usize) -> DseResult {
    let base_plan = crate::flow::default_factors(graph);
    let groups: Vec<ParamGroup> = base_plan.group_tiles.keys().copied().collect();

    let mut best_plan = base_plan.clone();
    let mut log = Vec::new();
    let mut evaluated = 0;
    let mut best_fps = eval(flow, graph, Mode::Folded, &best_plan, &mut log, &mut evaluated);

    for g in &groups {
        let mut candidates: Vec<(u64, u64)> = Vec::new();
        for &a in &TILE_CANDIDATES {
            for &b in &TILE_CANDIDATES {
                candidates.push((a, b));
            }
        }
        candidates.truncate(budget_per_group.max(1));
        for (t_ic, t_oc) in candidates {
            let mut plan = best_plan.clone();
            plan.group_tiles.insert(*g, (t_ic, t_oc));
            let fps = eval(flow, graph, Mode::Folded, &plan, &mut log, &mut evaluated);
            if fps > best_fps {
                best_fps = fps;
                best_plan = plan;
            }
        }
    }

    let best = log
        .iter()
        .filter(|p| p.rejected.is_none())
        .max_by(|a, b| a.fps.total_cmp(&b.fps))
        .cloned();
    DseResult { best, log, evaluated }
}

/// Sweep pipelined unroll caps.
pub fn explore_pipelined(flow: &Flow, graph: &Graph) -> DseResult {
    let mut log = Vec::new();
    let mut evaluated = 0;
    for cap in [16u64, 32, 64, 128, 256, 512, 1024] {
        let mut plan = crate::flow::default_factors(graph);
        plan.pipelined_cap = cap;
        eval(flow, graph, Mode::Pipelined, &plan, &mut log, &mut evaluated);
    }
    let best = log
        .iter()
        .filter(|p| p.rejected.is_none())
        .max_by(|a, b| a.fps.total_cmp(&b.fps))
        .cloned();
    DseResult { best, log, evaluated }
}

fn eval(
    flow: &Flow,
    graph: &Graph,
    mode: Mode,
    plan: &FactorPlan,
    log: &mut Vec<DsePoint>,
    evaluated: &mut usize,
) -> f64 {
    *evaluated += 1;
    match flow.compile_with(graph, mode, &OptConfig::optimized(), plan) {
        Ok(acc) => {
            let u = &acc.synthesis.resources.utilization;
            let fps = acc.performance.fps;
            log.push(DsePoint {
                plan: plan.clone(),
                fps,
                fmax_mhz: acc.synthesis.fmax_mhz,
                dsp_frac: u.dsp_frac,
                logic_frac: u.logic_frac,
                bram_frac: u.bram_frac,
                rejected: None,
            });
            fps
        }
        Err(e) => {
            log.push(DsePoint {
                plan: plan.clone(),
                fps: 0.0,
                fmax_mhz: 0.0,
                dsp_frac: 0.0,
                logic_frac: 0.0,
                bram_frac: 0.0,
                rejected: Some(e.to_string()),
            });
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;

    #[test]
    fn pipelined_dse_finds_a_design() {
        let flow = Flow::new();
        let r = explore_pipelined(&flow, &models::lenet5());
        let best = r.best.expect("some design routes");
        assert!(best.fps > 1000.0);
        assert!(r.evaluated >= 7);
    }

    #[test]
    fn folded_dse_improves_or_matches_default() {
        let flow = Flow::new();
        let g = models::mobilenet_v1();
        let default_fps = flow
            .compile(&g, Mode::Folded, crate::flow::OptLevel::Optimized)
            .unwrap()
            .performance
            .fps;
        let r = explore_folded(&flow, &g, 12);
        let best = r.best.expect("best exists");
        assert!(best.fps >= default_fps * 0.99, "dse {} vs default {}", best.fps, default_fps);
    }

    #[test]
    fn dse_log_contains_rejections_for_huge_tiles() {
        // Force an oversized sweep on ResNet: 64×64 tiles on the 3×3 group
        // would be 36K lanes — must be rejected (rule 3 / routing).
        let flow = Flow::new();
        let g = models::resnet34();
        let mut plan = crate::flow::default_factors(&g);
        for (_, t) in plan.group_tiles.iter_mut() {
            *t = (64, 64);
        }
        let mut log = Vec::new();
        let mut n = 0;
        let fps = eval(&flow, &g, Mode::Folded, &plan, &mut log, &mut n);
        assert_eq!(fps, 0.0);
        assert!(log[0].rejected.is_some());
    }
}
