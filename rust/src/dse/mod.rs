//! Design-space explorer over unroll/tile factors — the automation the
//! paper leaves to future work (§IV-J: "we manually sweep through several
//! parameter values … Ideally, a design space explorer (DSE) can be
//! developed to automate this process").
//!
//! The explorer sweeps candidate (t_ic, t_oc) tiles per parameterized
//! group (folded) or per-kernel unroll caps (pipelined), applies the three
//! §IV-J legality rules through the staged flow, and keeps the best
//! simulated-FPS design. Candidate tiles are ordered diagonal-first
//! (balanced tiles from small to large, then increasingly skewed pairs) so
//! a small budget still samples the whole magnitude range instead of only
//! the lexicographically-first corner of the grid.
//!
//! Because many candidate tiles clamp to the same effective factors (rule
//! 2 divisibility) the sweep revisits identical kernel programs; the
//! [`Compiler`]'s synthesis memo turns those into cache hits, reported in
//! [`DseResult::synth_cache`].
//!
//! [`explore_precisions`] adds datapath precision as a search dimension:
//! each precision is quantized through [`crate::quant`] (calibration +
//! Q/DQ rewrite + modeled top-1 loss) and swept like any other factor; the
//! accepted points collapse into an accuracy-vs-FPS-vs-resources Pareto
//! front ([`PrecisionFront`]).
//!
//! Candidate evaluation runs on [`crate::util::pool`] workers: groups
//! stay sequential (coordinate descent), but the candidates within a
//! group — which are independent given the best plan so far — fan out and
//! merge back by submission index, so the log order and the selected
//! design are identical to the sequential sweep. [`DseResult`] reports
//! the wall-clock vs summed-per-point time ([`DseResult::parallel_speedup`]).
//!
//! [`ablate_passes`] exploits the pass-pipeline refactor for real
//! ablations: deselecting an optimization rebuilds the design through the
//! [`crate::pass::PassManager`] with that pass removed from the pipeline.

use std::sync::Arc;
use std::time::Instant;

use crate::flow::{patterns::FactorPlan, CacheStats, Compiler, Mode, OptConfig};
use crate::graph::{Graph, ParamGroup};
use crate::quant::{self, QuantConfig};
use crate::schedule::OptKind;
use crate::texpr::Precision;
use crate::util::pool::Pool;

/// One evaluated design point.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub plan: FactorPlan,
    pub fps: f64,
    pub fmax_mhz: f64,
    pub dsp_frac: f64,
    pub logic_frac: f64,
    pub bram_frac: f64,
    /// Datapath precision this point was scheduled at.
    pub precision: Precision,
    /// Modeled top-1 loss at this precision (0 for fp32).
    pub accuracy_delta_pp: f64,
    /// None = synthesized; Some(reason) = rejected.
    pub rejected: Option<String>,
}

/// Exploration result: best design + full log.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub best: Option<DsePoint>,
    pub log: Vec<DsePoint>,
    pub evaluated: usize,
    /// Synthesis-memo hits/misses attributable to this exploration.
    pub synth_cache: CacheStats,
    /// Wall-clock seconds the sweep took (candidates within a group run
    /// on parallel [`Pool`] workers).
    pub wall_s: f64,
    /// Summed per-point evaluation seconds across all workers — the
    /// sequential-equivalent cost of the same sweep.
    pub cpu_s: f64,
}

impl DseResult {
    /// Fraction of synthesis requests served from the memo during the
    /// sweep.
    pub fn synth_cache_hit_rate(&self) -> f64 {
        self.synth_cache.hit_rate()
    }

    /// Wall-clock speedup of the parallel sweep over its
    /// sequential-equivalent cost (`cpu_s / wall_s`; 1.0 when unknown).
    pub fn parallel_speedup(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.cpu_s / self.wall_s
        } else {
            1.0
        }
    }
}

/// Worker count for candidate evaluation: the host's parallelism, kept in
/// [2, 8] so laptop sweeps parallelize and CI runners don't oversubscribe.
fn dse_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8)
}

/// Candidate per-dimension tile factors (powers of two are router-friendly
/// and divide the evaluation networks' channel counts).
pub const TILE_CANDIDATES: [u64; 7] = [1, 2, 4, 8, 16, 32, 64];

/// The full (t_ic, t_oc) candidate grid, ordered diagonal-first: balanced
/// pairs from small to large, then pairs of growing imbalance. Truncating
/// this order to any budget keeps coverage of the whole magnitude range —
/// the previous lexicographic `truncate` never reached tiles ≥ 16 for any
/// realistic budget.
pub fn tile_candidates_ordered() -> Vec<(u64, u64)> {
    let n = TILE_CANDIDATES.len();
    let mut idx: Vec<(usize, usize)> = Vec::with_capacity(n * n);
    for i in 0..n {
        for j in 0..n {
            idx.push((i, j));
        }
    }
    idx.sort_by_key(|&(i, j)| (i.abs_diff(j), i + j, i));
    idx.into_iter().map(|(i, j)| (TILE_CANDIDATES[i], TILE_CANDIDATES[j])).collect()
}

/// Sweep folded-mode tiles for every parameterized group, one group at a
/// time (coordinate descent: groups are resource-coupled but the paper's
/// manual sweep treats them independently too).
pub fn explore_folded(compiler: &Compiler, graph: &Graph, budget_per_group: usize) -> DseResult {
    explore_folded_with(compiler, graph, budget_per_group, &OptConfig::optimized(), 0.0)
}

/// [`explore_folded`] under an explicit optimization config (the precision
/// sweep's per-precision leg); `accuracy_delta_pp` is stamped on every
/// point.
pub fn explore_folded_with(
    compiler: &Compiler,
    graph: &Graph,
    budget_per_group: usize,
    cfg: &OptConfig,
    accuracy_delta_pp: f64,
) -> DseResult {
    let cache_before = compiler.cache_stats();
    let sweep_start = Instant::now();
    let mut cpu_s = 0.0;
    let base_plan = crate::flow::default_factors(graph);
    let groups: Vec<ParamGroup> = base_plan.group_tiles.keys().copied().collect();

    let mut log: Vec<DsePoint> = Vec::new();
    let mut evaluated = 0usize;

    // Baseline: the default plan (sequential — everything compares to it).
    let mut best_plan = base_plan.clone();
    let t0 = Instant::now();
    let baseline = point_of(compiler, graph, Mode::Folded, cfg, accuracy_delta_pp, &best_plan);
    cpu_s += t0.elapsed().as_secs_f64();
    evaluated += 1;
    let mut best_fps = baseline.fps;
    log.push(baseline);

    let mut candidates = tile_candidates_ordered();
    candidates.truncate(budget_per_group.max(1));
    let shared_graph = Arc::new(graph.clone());
    let pool = Pool::new(dse_workers(), "dse");

    // Coordinate descent over groups (sequential), parallel within a
    // group: each candidate overwrites only this group's tile in the
    // best-so-far plan, so candidates are independent. Results merge by
    // submission index, which reproduces the sequential sweep's log order
    // and argmax (ties keep the earliest candidate) deterministically.
    for g in &groups {
        let handles: Vec<_> = candidates
            .iter()
            .map(|&(t_ic, t_oc)| {
                let compiler = compiler.clone();
                let graph = Arc::clone(&shared_graph);
                let cfg = *cfg;
                let mut plan = best_plan.clone();
                plan.group_tiles.insert(*g, (t_ic, t_oc));
                pool.submit_with_result(move || {
                    let t = Instant::now();
                    let p =
                        point_of(&compiler, &graph, Mode::Folded, &cfg, accuracy_delta_pp, &plan);
                    (p, t.elapsed().as_secs_f64())
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let (p, dt) = h.recv().unwrap_or_else(|_| {
                panic!(
                    "dse worker panicked evaluating candidate {i} of group {g:?} \
                     (panic payload is on the worker thread's stderr)"
                )
            });
            cpu_s += dt;
            evaluated += 1;
            if p.rejected.is_none() && p.fps > best_fps {
                best_fps = p.fps;
                best_plan = p.plan.clone();
            }
            log.push(p);
        }
    }

    finish(log, evaluated, compiler, cache_before, sweep_start.elapsed().as_secs_f64(), cpu_s)
}

/// Sweep pipelined unroll caps.
pub fn explore_pipelined(compiler: &Compiler, graph: &Graph) -> DseResult {
    explore_pipelined_with(compiler, graph, &OptConfig::optimized(), 0.0)
}

/// [`explore_pipelined`] under an explicit optimization config. The caps
/// are independent, so all of them evaluate on the worker pool at once.
pub fn explore_pipelined_with(
    compiler: &Compiler,
    graph: &Graph,
    cfg: &OptConfig,
    accuracy_delta_pp: f64,
) -> DseResult {
    let cache_before = compiler.cache_stats();
    let sweep_start = Instant::now();
    let shared_graph = Arc::new(graph.clone());
    let pool = Pool::new(dse_workers(), "dse");
    let handles: Vec<_> = [16u64, 32, 64, 128, 256, 512, 1024]
        .into_iter()
        .map(|cap| {
            let compiler = compiler.clone();
            let graph = Arc::clone(&shared_graph);
            let cfg = *cfg;
            pool.submit_with_result(move || {
                let mut plan = crate::flow::default_factors(&graph);
                plan.pipelined_cap = cap;
                let t = Instant::now();
                let p = point_of(&compiler, &graph, Mode::Pipelined, &cfg, accuracy_delta_pp, &plan);
                (p, t.elapsed().as_secs_f64())
            })
        })
        .collect();
    let mut log = Vec::new();
    let mut cpu_s = 0.0;
    for (i, h) in handles.into_iter().enumerate() {
        let (p, dt) = h.recv().unwrap_or_else(|_| {
            panic!(
                "dse worker panicked evaluating pipelined cap #{i} \
                 (panic payload is on the worker thread's stderr)"
            )
        });
        cpu_s += dt;
        log.push(p);
    }
    let evaluated = log.len();
    finish(log, evaluated, compiler, cache_before, sweep_start.elapsed().as_secs_f64(), cpu_s)
}

fn finish(
    log: Vec<DsePoint>,
    evaluated: usize,
    compiler: &Compiler,
    cache_before: CacheStats,
    wall_s: f64,
    cpu_s: f64,
) -> DseResult {
    let best = log
        .iter()
        .filter(|p| p.rejected.is_none())
        .max_by(|a, b| a.fps.total_cmp(&b.fps))
        .cloned();
    let after = compiler.cache_stats();
    let synth_cache = CacheStats {
        hits: after.hits - cache_before.hits,
        misses: after.misses - cache_before.misses,
    };
    DseResult { best, log, evaluated, synth_cache, wall_s, cpu_s }
}

/// Evaluate one plan into a [`DsePoint`], folding failures (legality,
/// routing) into `rejected` so the log keeps every candidate.
fn point_of(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    cfg: &OptConfig,
    accuracy_delta_pp: f64,
    plan: &FactorPlan,
) -> DsePoint {
    // Candidates evaluate on pool workers, so each span is a root on its
    // worker's Perfetto track. Cache attribution is the compiler-wide
    // hit-counter delta around this evaluation: exact under the sweeps'
    // coordinate-descent structure (candidates running concurrently each
    // synthesize a distinct plan, so a hit observed here is this
    // candidate's own).
    let mut span = crate::obs::span("dse", "candidate");
    let cache_before =
        if crate::obs::enabled() { Some(compiler.cache_stats()) } else { None };
    let p = point_of_inner(compiler, graph, mode, cfg, accuracy_delta_pp, plan);
    if let Some(before) = cache_before {
        let after = compiler.cache_stats();
        span.set_arg("synth_cache_hit", after.hits > before.hits);
        span.set_arg("mode", mode.name());
        span.set_arg("precision", cfg.precision.name());
        span.set_arg("fps", p.fps);
        span.set_arg("accepted", p.rejected.is_none());
        let m = crate::obs::global_metrics();
        m.counter("flow_dse_candidates_total", "DSE candidate evaluations").inc();
        if p.rejected.is_some() {
            m.counter("flow_dse_candidates_rejected_total", "DSE candidates rejected").inc();
        }
    }
    p
}

fn point_of_inner(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    cfg: &OptConfig,
    accuracy_delta_pp: f64,
    plan: &FactorPlan,
) -> DsePoint {
    match eval_point(compiler, graph, mode, cfg, accuracy_delta_pp, plan) {
        Ok(p) => p,
        Err(e) => DsePoint {
            plan: plan.clone(),
            fps: 0.0,
            fmax_mhz: 0.0,
            dsp_frac: 0.0,
            logic_frac: 0.0,
            bram_frac: 0.0,
            precision: cfg.precision,
            accuracy_delta_pp,
            rejected: Some(e.to_string()),
        },
    }
}

/// Evaluate one design point through the staged API: the explorer only
/// needs the synthesis report and the performance numbers, so no per-point
/// `Accelerator` (with its kernel-program deep copy) is materialized.
fn eval_point(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    cfg: &OptConfig,
    accuracy_delta_pp: f64,
    plan: &FactorPlan,
) -> crate::Result<DsePoint> {
    let mut session = compiler.graph(graph).mode(mode).opts(*cfg).plan(plan.clone());
    session.lower()?;
    let design = session.synthesize()?;
    let u = design.synthesis.resources.utilization;
    let perf = design.performance();
    Ok(DsePoint {
        plan: plan.clone(),
        fps: perf.fps,
        fmax_mhz: design.synthesis.fmax_mhz,
        dsp_frac: u.dsp_frac,
        logic_frac: u.logic_frac,
        bram_frac: u.bram_frac,
        precision: cfg.precision,
        accuracy_delta_pp,
        rejected: None,
    })
}

/// One pipeline-subset evaluation: the full pipeline (`disabled: None`)
/// or the pipeline with one pass deselected.
#[derive(Debug, Clone)]
pub struct AblationPoint {
    /// The pass removed from the pipeline (`None` = full pipeline).
    pub disabled: Option<OptKind>,
    pub fps: f64,
    /// Table III row of the resulting design (empty when it failed).
    pub applied: Vec<OptKind>,
    /// Rejection reason when the subset failed to compile/route.
    pub rejected: Option<String>,
}

/// Pipeline-subset ablation: because every optimization is a registered
/// pass selected by [`OptConfig`], deselecting one is a real pipeline
/// permutation — the design is rebuilt by the
/// [`crate::pass::PassManager`] without that pass, not patched up. The
/// first point is the full pipeline; each subsequent point removes one of
/// `kinds`.
pub fn ablate_passes(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    kinds: &[OptKind],
) -> Vec<AblationPoint> {
    let plan = crate::flow::default_factors(graph);
    let mut points = Vec::with_capacity(kinds.len() + 1);
    points.push(ablation_point(compiler, graph, mode, &OptConfig::optimized(), &plan, None));
    for &k in kinds {
        let cfg = OptConfig::optimized().without(k);
        points.push(ablation_point(compiler, graph, mode, &cfg, &plan, Some(k)));
    }
    points
}

fn ablation_point(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    cfg: &OptConfig,
    plan: &FactorPlan,
    disabled: Option<OptKind>,
) -> AblationPoint {
    match compiler.compile_with(graph, mode, cfg, plan) {
        Ok(acc) => AblationPoint {
            disabled,
            fps: acc.performance.fps,
            applied: acc.applied.clone(),
            rejected: None,
        },
        Err(e) => AblationPoint {
            disabled,
            fps: 0.0,
            applied: Vec::new(),
            rejected: Some(e.to_string()),
        },
    }
}

/// One point of the accuracy-vs-FPS-vs-resources trade-off surface.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    pub precision: Precision,
    pub fps: f64,
    pub fmax_mhz: f64,
    pub dsp_frac: f64,
    pub logic_frac: f64,
    pub bram_frac: f64,
    pub accuracy_delta_pp: f64,
    pub plan: FactorPlan,
}

impl ParetoPoint {
    fn from_dse(p: &DsePoint) -> ParetoPoint {
        ParetoPoint {
            precision: p.precision,
            fps: p.fps,
            fmax_mhz: p.fmax_mhz,
            dsp_frac: p.dsp_frac,
            logic_frac: p.logic_frac,
            bram_frac: p.bram_frac,
            accuracy_delta_pp: p.accuracy_delta_pp,
            plan: p.plan.clone(),
        }
    }

    /// Pareto dominance over (FPS↑, DSP↓, logic↓, BRAM↓, accuracy loss↓):
    /// at least as good everywhere, strictly better somewhere.
    pub fn dominates(&self, o: &ParetoPoint) -> bool {
        let no_worse = self.fps >= o.fps
            && self.dsp_frac <= o.dsp_frac
            && self.logic_frac <= o.logic_frac
            && self.bram_frac <= o.bram_frac
            && self.accuracy_delta_pp <= o.accuracy_delta_pp;
        no_worse
            && (self.fps > o.fps
                || self.dsp_frac < o.dsp_frac
                || self.logic_frac < o.logic_frac
                || self.bram_frac < o.bram_frac
                || self.accuracy_delta_pp < o.accuracy_delta_pp)
    }

    /// Strictly lower on *every* modeled resource at equal-or-better FPS —
    /// the "reduced precision actually pays" criterion (accuracy loss
    /// deliberately excluded; that trade-off is the front's job to expose).
    pub fn dominates_on_resources(&self, o: &ParetoPoint) -> bool {
        self.fps >= o.fps
            && self.dsp_frac < o.dsp_frac
            && self.logic_frac < o.logic_frac
            && self.bram_frac < o.bram_frac
    }
}

/// Result of a precision-dimension exploration: per-precision sweeps plus
/// the combined Pareto front.
#[derive(Debug, Clone)]
pub struct PrecisionFront {
    pub network: String,
    pub mode: Mode,
    /// The underlying sweep per precision, in input order.
    pub results: Vec<(Precision, DseResult)>,
    /// Non-dominated accepted points across all precisions.
    pub pareto: Vec<ParetoPoint>,
    /// Best-FPS accepted fp32 point (the baseline quantization must beat).
    pub baseline_f32: Option<ParetoPoint>,
}

impl PrecisionFront {
    /// Front points at one precision.
    pub fn at(&self, p: Precision) -> impl Iterator<Item = &ParetoPoint> {
        self.pareto.iter().filter(move |pt| pt.precision == p)
    }

    /// Does any point at `p` strictly beat the fp32 baseline on every
    /// modeled resource at equal-or-better FPS?
    pub fn beats_baseline_on_resources(&self, p: Precision) -> bool {
        match &self.baseline_f32 {
            Some(base) => self.at(p).any(|pt| pt.dominates_on_resources(base)),
            None => false,
        }
    }

    /// Total synthesis-cache statistics over all legs of the sweep.
    pub fn synth_cache(&self) -> CacheStats {
        self.results.iter().fold(CacheStats::default(), |acc, (_, r)| CacheStats {
            hits: acc.hits + r.synth_cache.hits,
            misses: acc.misses + r.synth_cache.misses,
        })
    }
}

/// Explore datapath precision as a DSE dimension: each precision is
/// quantized through [`crate::quant::prepare`] (BN-fold, calibration, Q/DQ
/// rewrite, modeled top-1 loss) and tile/unroll-swept like the plain
/// explorer; accepted points collapse into a Pareto front.
///
/// ```
/// use tvm_fpga_flow::dse::explore_precisions;
/// use tvm_fpga_flow::flow::{Compiler, Mode};
/// use tvm_fpga_flow::graph::models;
/// use tvm_fpga_flow::texpr::Precision;
///
/// let compiler = Compiler::default();
/// let front = explore_precisions(
///     &compiler,
///     &models::lenet5(),
///     Mode::Pipelined,
///     4,
///     &[Precision::F32, Precision::Int8],
/// )
/// .unwrap();
/// assert!(!front.pareto.is_empty());
/// // Reduced precision pays on this workload: some int8 design strictly
/// // beats the fp32 baseline on every modeled resource at ≥ its FPS.
/// assert!(front.beats_baseline_on_resources(Precision::Int8));
/// ```
pub fn explore_precisions(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    budget_per_group: usize,
    precisions: &[Precision],
) -> crate::Result<PrecisionFront> {
    explore_precisions_measured(compiler, graph, mode, budget_per_group, precisions, 0)
}

/// [`explore_precisions`] with *measured* accuracy: when `frames > 0` and
/// the network has a representative dataset, every quantized leg
/// calibrates on real frames and reports empirical held-out top-1 loss
/// (`estimated: false`) instead of the analytic noise model. The sweep is
/// affordable because calibration and measurement run arena-backed
/// ([`crate::quant::calibrate_in`] / `accuracy::measure_in`) — one
/// executor build plus zero steady-state allocations per frame.
/// `frames == 0`, or a network without a dataset, keeps the analytic
/// estimate (exactly [`explore_precisions`]).
pub fn explore_precisions_measured(
    compiler: &Compiler,
    graph: &Graph,
    mode: Mode,
    budget_per_group: usize,
    precisions: &[Precision],
    frames: usize,
) -> crate::Result<PrecisionFront> {
    // An fp32-only sweep must reproduce exactly what `compile` builds (raw
    // graph). As soon as a quantized leg participates, the fp32 baseline
    // runs the same graph-pass pipeline the quantized legs get, so the
    // front compares precision against precision — not BN-fold and DCE
    // smuggled in on one side.
    let comparing = precisions.iter().any(|&p| p != Precision::F32);
    let measured = frames > 0 && crate::data::for_network(&graph.name, 1, 0).is_some();
    let mut results: Vec<(Precision, DseResult)> = Vec::with_capacity(precisions.len());
    for &p in precisions {
        let cfg = OptConfig::optimized().with_precision(p);
        let (eval_graph, delta_pp);
        if p == Precision::F32 {
            eval_graph = if comparing {
                crate::graph::passes::standard_pipeline(graph).0
            } else {
                graph.clone()
            };
            delta_pp = 0.0;
        } else {
            let quant_cfg = if measured {
                QuantConfig::for_precision(p).with_data(frames)
            } else {
                QuantConfig::for_precision(p)
            };
            let prep = quant::prepare(graph, &quant_cfg)?;
            delta_pp = prep.report.accuracy.delta_pp;
            eval_graph = prep.graph;
        }
        let r = match mode {
            Mode::Folded => {
                explore_folded_with(compiler, &eval_graph, budget_per_group, &cfg, delta_pp)
            }
            Mode::Pipelined => explore_pipelined_with(compiler, &eval_graph, &cfg, delta_pp),
        };
        results.push((p, r));
    }

    let accepted: Vec<ParetoPoint> = results
        .iter()
        .flat_map(|(_, r)| r.log.iter().filter(|p| p.rejected.is_none()).map(ParetoPoint::from_dse))
        .collect();
    let pareto: Vec<ParetoPoint> = accepted
        .iter()
        .enumerate()
        .filter(|&(i, p)| {
            !accepted
                .iter()
                .enumerate()
                .any(|(j, o)| j != i && (o.dominates(p) || (j < i && points_equal(o, p))))
        })
        .map(|(_, p)| p.clone())
        .collect();
    let baseline_f32 = results
        .iter()
        .find(|(p, _)| *p == Precision::F32)
        .and_then(|(_, r)| r.best.as_ref())
        .map(ParetoPoint::from_dse);
    Ok(PrecisionFront { network: graph.name.clone(), mode, results, pareto, baseline_f32 })
}

/// One evaluated pipeline-partition candidate: a set of cut points, the
/// per-stage costs under the latency-balancing model, and the resulting
/// steady-state throughput (`1 / max_i stage_s`).
#[derive(Debug, Clone)]
pub struct PartitionPoint {
    /// Cut points in parent node ids (`stages = cuts.len() + 1`).
    pub cuts: Vec<usize>,
    /// Per-stage modeled cost, in stage order (empty when rejected).
    pub costs: Vec<crate::pass::StageCost>,
    /// Steady-state pipeline FPS (0 when rejected).
    pub fps: f64,
    /// Index of the slowest stage.
    pub bottleneck: usize,
    /// None = legal and fits; Some(reason) = rejected.
    pub rejected: Option<String>,
}

impl PartitionPoint {
    /// Pipeline interval: the bottleneck stage's occupancy.
    pub fn interval_s(&self) -> f64 {
        self.costs.iter().map(|c| c.stage_s()).fold(0.0, f64::max)
    }

    /// Total bytes crossing host links per frame.
    pub fn total_transfer_bytes(&self) -> u64 {
        self.costs.iter().map(|c| c.transfer_bytes).sum()
    }
}

/// Result of a partition search: the chosen cuts plus the full candidate
/// log and the synthesis-memo statistics the sweep accumulated.
#[derive(Debug, Clone)]
pub struct PartitionResult {
    pub best: Option<PartitionPoint>,
    pub log: Vec<PartitionPoint>,
    pub evaluated: usize,
    pub synth_cache: CacheStats,
}

/// Search pipeline cut points for a K-device deployment (`K =
/// targets.len()`, possibly heterogeneous): enumerate every choose(K-1)
/// combination of the clean spatial-reduction cut candidates
/// ([`crate::pass::candidate_cuts`]), synthesize each stage on its device
/// through the staged session API (sharing one synthesis memo per distinct
/// target, so revisited stage subgraphs are cache hits), and keep the
/// combination minimizing the bottleneck stage time `max_i max(compute_i,
/// transfer_i)` — ties broken toward fewer total transfer bytes. Stages
/// whose modeled design does not fit their device are rejected candidates,
/// not errors: a network too big for any single device is exactly the case
/// partitioning exists for.
pub fn explore_partitions(
    graph: &Graph,
    targets: &[&str],
    link: &crate::flow::multi::Link,
) -> crate::Result<PartitionResult> {
    anyhow::ensure!(!targets.is_empty(), "partition search needs at least one target");
    // One compiler (= one synthesis memo) per *distinct* target name;
    // per-stage handles are clones sharing it.
    let mut by_name: std::collections::BTreeMap<&str, Compiler> = std::collections::BTreeMap::new();
    for name in targets {
        if let std::collections::btree_map::Entry::Vacant(e) = by_name.entry(*name) {
            e.insert(Compiler::for_target(name)?);
        }
    }
    let compilers: Vec<Compiler> = targets.iter().map(|n| by_name[n].clone()).collect();
    let r = explore_partitions_with(graph, &compilers, link);
    Ok(r)
}

/// [`explore_partitions`] over pre-built per-stage compilers (stage i runs
/// on `compilers[i]`'s target). Exposed so a caller materializing the
/// winning plan can reuse the same synthesis memos.
pub fn explore_partitions_with(
    graph: &Graph,
    compilers: &[Compiler],
    link: &crate::flow::multi::Link,
) -> PartitionResult {
    let cache_before = partition_cache_stats(compilers);
    let k = compilers.len();
    let combos: Vec<Vec<usize>> = if k == 1 {
        vec![Vec::new()]
    } else {
        combinations(&crate::pass::candidate_cuts(graph), k - 1)
    };
    let mut log = Vec::with_capacity(combos.len());
    for cuts in combos {
        let mut span = crate::obs::span("dse", "partition");
        let p = partition_point(graph, compilers, link, cuts);
        if crate::obs::enabled() {
            span.set_arg("cuts", format!("{:?}", p.cuts));
            span.set_arg("fps", p.fps);
            span.set_arg("accepted", p.rejected.is_none());
            let m = crate::obs::global_metrics();
            m.counter("flow_dse_partitions_total", "partition candidate evaluations").inc();
            if p.rejected.is_some() {
                m.counter(
                    "flow_dse_partitions_rejected_total",
                    "partition candidates rejected",
                )
                .inc();
            }
        }
        log.push(p);
    }
    let best = log
        .iter()
        .filter(|p| p.rejected.is_none())
        .min_by(|a, b| {
            a.interval_s()
                .total_cmp(&b.interval_s())
                .then(a.total_transfer_bytes().cmp(&b.total_transfer_bytes()))
        })
        .cloned();
    let after = partition_cache_stats(compilers);
    let evaluated = log.len();
    PartitionResult {
        best,
        log,
        evaluated,
        synth_cache: CacheStats {
            hits: after.hits - cache_before.hits,
            misses: after.misses - cache_before.misses,
        },
    }
}

/// Summed memo counters over the *distinct* memos in `compilers` (clones
/// share counters; double-counting would inflate the hit rate).
fn partition_cache_stats(compilers: &[Compiler]) -> CacheStats {
    let mut seen = std::collections::BTreeSet::new();
    let mut total = CacheStats::default();
    for c in compilers {
        if seen.insert(c.target.name.as_str()) {
            let s = c.cache_stats();
            total.hits += s.hits;
            total.misses += s.misses;
        }
    }
    total
}

/// Evaluate one cut combination into a [`PartitionPoint`], folding
/// illegality, synthesis failure, and budget overflow into `rejected`.
fn partition_point(
    graph: &Graph,
    compilers: &[Compiler],
    link: &crate::flow::multi::Link,
    cuts: Vec<usize>,
) -> PartitionPoint {
    let rejected = |cuts: Vec<usize>, why: String| PartitionPoint {
        cuts,
        costs: Vec::new(),
        fps: 0.0,
        bottleneck: 0,
        rejected: Some(why),
    };
    let Some(stages) = crate::pass::split_stages(graph, &cuts) else {
        return rejected(cuts, "cut is not a clean single-value frontier".into());
    };
    let mut costs = Vec::with_capacity(stages.len());
    for (i, stage) in stages.iter().enumerate() {
        let compiler = &compilers[i];
        let mut session = compiler.graph(&stage.graph).mode(crate::flow::ModeChoice::Auto);
        if let Err(e) = session.lower() {
            return rejected(cuts, format!("stage {i} on {}: {e}", compiler.target.name));
        }
        let design = match session.synthesize() {
            Ok(d) => d,
            Err(e) => {
                return rejected(cuts, format!("stage {i} on {}: {e}", compiler.target.name))
            }
        };
        let util = design.synthesis.resources.utilization;
        if !util.fits() {
            let (dim, frac) = util.peak();
            return rejected(
                cuts,
                format!(
                    "stage {i} does not fit {}: {dim} at {:.0}%",
                    compiler.target.name,
                    frac * 100.0
                ),
            );
        }
        let compute_s = design.performance().frame_time_s;
        let transfer_bytes = if i == 0 { 0 } else { stage.input_bytes() };
        costs.push(if i == 0 {
            crate::pass::StageCost { compute_s, transfer_s: 0.0, transfer_bytes: 0 }
        } else {
            crate::pass::StageCost::model(compute_s, transfer_bytes, link)
        });
    }
    let (bottleneck, interval) = costs
        .iter()
        .enumerate()
        .map(|(i, c)| (i, c.stage_s()))
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .expect("at least one stage");
    PartitionPoint { cuts, costs, fps: 1.0 / interval, bottleneck, rejected: None }
}

/// All choose(k) combinations of `items`, preserving order.
fn combinations(items: &[usize], k: usize) -> Vec<Vec<usize>> {
    if k == 0 {
        return vec![Vec::new()];
    }
    if items.len() < k {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, &x) in items.iter().enumerate() {
        for mut rest in combinations(&items[i + 1..], k - 1) {
            rest.insert(0, x);
            out.push(rest);
        }
    }
    out
}

/// Metric-space equality (used to drop duplicate front entries that came
/// from tile candidates clamping to the same design).
fn points_equal(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    a.precision == b.precision
        && a.fps == b.fps
        && a.dsp_frac == b.dsp_frac
        && a.logic_frac == b.logic_frac
        && a.bram_frac == b.bram_frac
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::models;
    use crate::graph::GroupKind;

    #[test]
    fn pipelined_dse_finds_a_design() {
        let compiler = Compiler::default();
        let r = explore_pipelined(&compiler, &models::lenet5());
        let best = r.best.expect("some design routes");
        assert!(best.fps > 1000.0);
        assert!(r.evaluated >= 7);
    }

    #[test]
    fn folded_dse_improves_or_matches_default() {
        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let default_fps = compiler
            .compile(&g, Mode::Folded, crate::flow::OptLevel::Optimized)
            .unwrap()
            .performance
            .fps;
        let r = explore_folded(&compiler, &g, 12);
        let best = r.best.expect("best exists");
        assert!(best.fps >= default_fps * 0.99, "dse {} vs default {}", best.fps, default_fps);
    }

    #[test]
    fn dse_log_contains_rejections_for_huge_tiles() {
        // Force an oversized sweep on ResNet: 64×64 tiles on the 3×3 group
        // would be 36K lanes — must be rejected (rule 3 / routing).
        let compiler = Compiler::default();
        let g = models::resnet34();
        let mut plan = crate::flow::default_factors(&g);
        for (_, t) in plan.group_tiles.iter_mut() {
            *t = (64, 64);
        }
        let p = point_of(&compiler, &g, Mode::Folded, &OptConfig::optimized(), 0.0, &plan);
        assert_eq!(p.fps, 0.0);
        assert!(p.rejected.is_some());
        assert_eq!(p.precision, Precision::F32);
    }

    #[test]
    fn parallel_sweep_is_deterministic_and_reports_speedup() {
        // Fresh compiler (= fresh synthesis memo) per run: with the
        // single-flight memo the hit/miss split is deterministic too —
        // misses = distinct programs, hits = revisits.
        let g = models::mobilenet_v1();
        let a = explore_folded(&Compiler::default(), &g, 8);
        let b = explore_folded(&Compiler::default(), &g, 8);
        assert_eq!(a.evaluated, b.evaluated);
        assert_eq!(a.log.len(), b.log.len());
        assert_eq!(a.synth_cache, b.synth_cache, "cache counters must be deterministic");
        // Merge-by-index keeps the log in the sequential sweep's order.
        for (x, y) in a.log.iter().zip(&b.log) {
            assert_eq!(x.fps, y.fps);
            assert_eq!(x.plan.group_tiles, y.plan.group_tiles);
            assert_eq!(x.rejected.is_some(), y.rejected.is_some());
        }
        assert_eq!(a.best.as_ref().map(|p| p.fps), b.best.as_ref().map(|p| p.fps));
        // Wall-clock accounting is populated; cpu time covers all points.
        assert!(a.wall_s > 0.0);
        assert!(a.cpu_s > 0.0);
        assert!(a.parallel_speedup() > 0.0);
    }

    #[test]
    fn ablation_rebuilds_pipeline_subsets() {
        let compiler = Compiler::default();
        let g = models::lenet5();
        let kinds = [OptKind::Unroll, OptKind::Channels, OptKind::CachedWrite];
        let points = ablate_passes(&compiler, &g, Mode::Pipelined, &kinds);
        assert_eq!(points.len(), kinds.len() + 1);
        let full = &points[0];
        assert_eq!(full.disabled, None);
        assert!(full.rejected.is_none());
        for p in &points[1..] {
            let k = p.disabled.expect("ablated point names its pass");
            assert!(
                !p.applied.contains(&k),
                "{k:?} still applied after deselection: {:?}",
                p.applied
            );
        }
        // Unrolling is the dominant lever on LeNet — removing it hurts.
        let no_unroll =
            points.iter().find(|p| p.disabled == Some(OptKind::Unroll)).unwrap();
        assert!(no_unroll.fps < full.fps);
    }

    #[test]
    fn candidate_order_is_diagonal_first_and_complete() {
        let c = tile_candidates_ordered();
        assert_eq!(c.len(), TILE_CANDIDATES.len() * TILE_CANDIDATES.len());
        // Balanced tiles lead, small to large.
        assert_eq!(&c[..7], &[(1, 1), (2, 2), (4, 4), (8, 8), (16, 16), (32, 32), (64, 64)]);
        // No duplicates.
        let mut seen = std::collections::BTreeSet::new();
        assert!(c.iter().all(|t| seen.insert(*t)));
    }

    #[test]
    fn budget_12_still_evaluates_large_tiles() {
        // Regression for the old `candidates.truncate(budget)` bug, which
        // kept only the lexicographically-first (all-small) tile pairs: a
        // budget of 12 must still evaluate at least one tile ≥ 16 for the
        // swept groups — checked on a depthwise group whose default tile
        // is (8, 1), so any ≥16 entry can only come from the sweep.
        let first12 = tile_candidates_ordered().into_iter().take(12).collect::<Vec<_>>();
        assert!(first12.iter().any(|&(a, b)| a.max(b) >= 16), "{first12:?}");

        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let r = explore_folded(&compiler, &g, 12);
        let dw = ParamGroup { kind: GroupKind::Depthwise, kernel: 3, stride: 1 };
        assert!(
            r.log.iter().any(|p| p
                .plan
                .group_tiles
                .get(&dw)
                .is_some_and(|&(a, b)| a.max(b) >= 16)),
            "no large depthwise tile was ever evaluated under budget 12"
        );
    }

    #[test]
    fn pareto_dominance_logic() {
        let base = ParetoPoint {
            precision: Precision::F32,
            fps: 100.0,
            fmax_mhz: 200.0,
            dsp_frac: 0.4,
            logic_frac: 0.5,
            bram_frac: 0.3,
            accuracy_delta_pp: 0.0,
            plan: FactorPlan::default(),
        };
        let better = ParetoPoint {
            precision: Precision::Int8,
            fps: 110.0,
            dsp_frac: 0.2,
            logic_frac: 0.4,
            bram_frac: 0.2,
            accuracy_delta_pp: 0.0,
            ..base.clone()
        };
        let lossy = ParetoPoint { accuracy_delta_pp: 1.5, ..better.clone() };
        assert!(better.dominates(&base));
        assert!(better.dominates_on_resources(&base));
        assert!(!base.dominates(&better));
        // Accuracy loss blocks full dominance but not the resource check.
        assert!(!lossy.dominates(&base));
        assert!(lossy.dominates_on_resources(&base));
        // A point never dominates itself.
        assert!(!base.dominates(&base));
    }

    #[test]
    fn precision_front_lenet_pipelined() {
        let compiler = Compiler::default();
        let front = explore_precisions(
            &compiler,
            &models::lenet5(),
            Mode::Pipelined,
            4,
            &[Precision::F32, Precision::Int8, Precision::F16],
        )
        .unwrap();
        assert_eq!(front.results.len(), 3);
        let base = front.baseline_f32.as_ref().expect("f32 baseline exists");
        assert!(base.fps > 0.0);
        assert!(!front.pareto.is_empty());
        // The front carries accuracy deltas: fp32 exact, int8 lossy-but-bounded.
        assert!(front.at(Precision::Int8).all(|p| p.accuracy_delta_pp > 0.0));
        assert!(front.at(Precision::Int8).all(|p| p.accuracy_delta_pp < 25.0));
        // No front point is dominated by any other.
        for (i, p) in front.pareto.iter().enumerate() {
            for (j, o) in front.pareto.iter().enumerate() {
                assert!(i == j || !o.dominates(p), "front point {i} dominated by {j}");
            }
        }
    }

    #[test]
    fn measured_precision_front_uses_real_frames() {
        let compiler = Compiler::default();
        let front = explore_precisions_measured(
            &compiler,
            &models::lenet5(),
            Mode::Pipelined,
            4,
            &[Precision::F32, Precision::Int8],
            8,
        )
        .unwrap();
        assert_eq!(front.results.len(), 2);
        assert!(!front.pareto.is_empty());
        // Measured int8 loss is the empirical held-out number, bounded
        // like the analytic band.
        assert!(front.at(Precision::Int8).all(|p| p.accuracy_delta_pp < 25.0));
        // frames == 0 degenerates to the analytic sweep.
        let analytic = explore_precisions_measured(
            &compiler,
            &models::lenet5(),
            Mode::Pipelined,
            4,
            &[Precision::F32, Precision::Int8],
            0,
        )
        .unwrap();
        let plain = explore_precisions(
            &compiler,
            &models::lenet5(),
            Mode::Pipelined,
            4,
            &[Precision::F32, Precision::Int8],
        )
        .unwrap();
        assert_eq!(analytic.pareto.len(), plain.pareto.len());
    }

    #[test]
    fn folded_dse_reports_synthesis_cache_hits() {
        // Depthwise groups ignore t_oc and small extents clamp large
        // tiles, so the sweep necessarily revisits identical programs —
        // the memo must convert those into hits.
        let compiler = Compiler::default();
        let g = models::mobilenet_v1();
        let r = explore_folded(&compiler, &g, 16);
        assert!(r.synth_cache.hits > 0, "{:?}", r.synth_cache);
        assert!(r.synth_cache_hit_rate() > 0.0);
        assert!(r.synth_cache.total() <= r.evaluated as u64);
    }

    #[test]
    fn combinations_enumerate_in_order() {
        assert_eq!(combinations(&[1, 2, 3], 0), vec![Vec::<usize>::new()]);
        assert_eq!(combinations(&[1, 2, 3], 2), vec![vec![1, 2], vec![1, 3], vec![2, 3]]);
        assert!(combinations(&[1, 2], 3).is_empty());
    }

    #[test]
    fn partition_search_balances_resnet_across_two_devices() {
        use crate::flow::multi::Link;
        let g = models::resnet34();
        let r = explore_partitions(&g, &["stratix10sx", "stratix10sx"], &Link::default())
            .unwrap();
        let best = r.best.as_ref().expect("a legal 2-stage partition exists");
        assert_eq!(best.cuts.len(), 1);
        assert_eq!(best.costs.len(), 2);
        assert!(best.bottleneck < 2);
        // The winner minimizes the bottleneck interval over the whole log.
        for p in r.log.iter().filter(|p| p.rejected.is_none()) {
            assert!(best.interval_s() <= p.interval_s() + 1e-12);
        }
        // Stage 1's inbound transfer crosses the host link.
        assert!(best.costs[1].transfer_bytes > 0);
        assert!(best.costs[0].transfer_bytes == 0);
        // Pipelining beats the best single-device folded plan.
        let single = Compiler::default()
            .compile(&g, Mode::Folded, crate::flow::OptLevel::Optimized)
            .unwrap()
            .performance
            .fps;
        assert!(best.fps > single * 1.2, "pipeline {} vs single {single}", best.fps);
        // The memo saw every stage synthesis of the sweep.
        assert!(r.synth_cache.total() > 0);
    }

    #[test]
    fn partition_search_degenerates_to_whole_graph_on_one_target() {
        use crate::flow::multi::Link;
        let g = models::lenet5();
        let r = explore_partitions(&g, &["stratix10sx"], &Link::default()).unwrap();
        let best = r.best.expect("whole-graph point accepted");
        assert!(best.cuts.is_empty());
        assert_eq!(best.costs.len(), 1);
        assert_eq!(best.total_transfer_bytes(), 0);
        assert_eq!(r.evaluated, 1);
    }

    #[test]
    fn partition_search_rejects_unknown_target() {
        use crate::flow::multi::Link;
        let g = models::lenet5();
        assert!(explore_partitions(&g, &["virtex7", "stratix10sx"], &Link::default()).is_err());
    }
}
