//! Differential fuzzing harness: randomized (network × pass-subset ×
//! precision × mode) scenarios through [`super::verify_program`], with a
//! greedy shrinker that reduces any counterexample to a minimal
//! (net, config, frame) reproducer.
//!
//! A [`Scenario`] is fully described by plain data (network name or chain
//! seed, mode, precision, enabled pass kinds, frame seed/index), so a CI
//! failure serializes to JSON ([`Reproducer`]), uploads as an artifact and
//! replays locally byte-for-byte. [`Fault`]s inject known-wrong programs
//! to prove the harness actually catches and shrinks mismatches (the
//! `forced-mismatch` self-test of `rust/tests/differential.rs`).

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::codegen::KernelProgram;
use crate::flow::patterns::{build_with_passes, default_factors, OptConfig};
use crate::flow::Mode;
use crate::graph::{models, Activation, Graph, GraphBuilder, Op, Shape};
use crate::schedule::OptKind;
use crate::texpr::Precision;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::scratch::Scratch;

use super::{frames_for, verify_program_in, VerifyOptions, VerifyReport};

/// Network under test: a named evaluation model or a seeded random chain.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NetSpec {
    Named(String),
    Chain { seed: u64 },
}

impl NetSpec {
    pub fn describe(&self) -> String {
        match self {
            NetSpec::Named(n) => n.clone(),
            NetSpec::Chain { seed } => format!("chain:{seed:#x}"),
        }
    }

    pub fn parse(s: &str) -> Option<NetSpec> {
        match s.strip_prefix("chain:") {
            Some(seed) => crate::util::rng::parse_seed(seed).map(|seed| NetSpec::Chain { seed }),
            None => Some(NetSpec::Named(s.to_string())),
        }
    }
}

/// One differential-testing scenario — plain data, fully replayable.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub net: NetSpec,
    pub mode: Mode,
    pub precision: Precision,
    /// Enabled optimization kinds (the pass subset under test).
    pub opts: Vec<OptKind>,
    /// Frames to verify (ignored when `frame` pins a single index).
    pub frames: usize,
    /// When set, verify only this frame index — the shrinker's output.
    pub frame: Option<usize>,
    /// Frame-generation seed.
    pub seed: u64,
}

/// The pass kinds the fuzzer toggles: the canonical Table-I pipeline
/// ([`crate::flow::patterns::CANONICAL_PIPELINE`] — the single source of
/// truth, so a newly registered pass is fuzzed automatically) plus the VT
/// extension. Q rides `precision`; SP is excluded because its value
/// semantics are cost-model-only.
pub fn fuzz_opts() -> Vec<OptKind> {
    crate::flow::patterns::CANONICAL_PIPELINE
        .iter()
        .copied()
        .chain(std::iter::once(OptKind::Vectorize))
        .collect()
}

impl Scenario {
    /// The materialized network.
    pub fn graph(&self) -> Graph {
        match &self.net {
            NetSpec::Named(n) => models::by_name(n).unwrap_or_else(|| {
                panic!("scenario names unknown network {n}")
            }),
            NetSpec::Chain { seed } => random_chain(*seed),
        }
    }

    /// The optimization config this scenario's pass subset selects.
    pub fn cfg(&self) -> OptConfig {
        let mut cfg = OptConfig::base();
        for o in &self.opts {
            match o {
                OptKind::Unroll => cfg.unroll = true,
                OptKind::Tile => cfg.tile = true,
                OptKind::Fuse => cfg.fuse = true,
                OptKind::CachedWrite => cfg.cached_writes = true,
                OptKind::FloatOpt => cfg.float_opt = true,
                OptKind::Channels => cfg.channels = true,
                OptKind::Autorun => cfg.autorun = true,
                OptKind::Concurrent => cfg.concurrent = true,
                OptKind::Parameterize => cfg.parameterize = true,
                OptKind::Vectorize => cfg.vectorize = true,
                OptKind::Sparsify => cfg.weight_density = 0.5,
                OptKind::Quantize => {}
            }
        }
        cfg.with_precision(self.precision)
    }

    pub fn describe(&self) -> String {
        format!(
            "{} {} {} opts=[{}] frames={}{} seed={:#x}",
            self.net.describe(),
            self.mode.name(),
            self.precision,
            self.opts.iter().map(|o| o.abbrev()).collect::<Vec<_>>().join(" "),
            self.frames,
            self.frame.map(|i| format!(" frame={i}")).unwrap_or_default(),
            self.seed
        )
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("net".into(), Json::Str(self.net.describe()));
        m.insert("mode".into(), Json::Str(self.mode.name().into()));
        m.insert("precision".into(), Json::Str(self.precision.name().into()));
        m.insert(
            "opts".into(),
            Json::Arr(self.opts.iter().map(|o| Json::Str(o.abbrev().into())).collect()),
        );
        m.insert("frames".into(), Json::Num(self.frames as f64));
        match self.frame {
            Some(i) => m.insert("frame".into(), Json::Num(i as f64)),
            None => m.insert("frame".into(), Json::Null),
        };
        m.insert("seed".into(), Json::Str(format!("{:#x}", self.seed)));
        Json::Obj(m)
    }

    /// Parse a scenario back from [`Scenario::to_json`] output (the replay
    /// path of an uploaded reproducer).
    pub fn from_json(j: &Json) -> Option<Scenario> {
        let net = NetSpec::parse(j.get("net")?.as_str()?)?;
        let mode = match j.get("mode")?.as_str()? {
            "pipelined" => Mode::Pipelined,
            "folded" => Mode::Folded,
            _ => return None,
        };
        let precision = Precision::parse(j.get("precision")?.as_str()?)?;
        // Strict: an unknown abbreviation means the reproducer came from
        // a different build (or was corrupted) — replaying a silently
        // weakened pass subset would mask the original failure.
        let opts = j
            .get("opts")?
            .as_arr()?
            .iter()
            .map(|o| o.as_str().and_then(opt_from_abbrev))
            .collect::<Option<Vec<OptKind>>>()?;
        let frames = j.get("frames")?.as_u64()? as usize;
        let frame = match j.get("frame") {
            Some(Json::Num(n)) => Some(*n as usize),
            _ => None,
        };
        let seed = crate::util::rng::parse_seed(j.get("seed")?.as_str()?)?;
        Some(Scenario { net, mode, precision, opts, frames, frame, seed })
    }
}

fn opt_from_abbrev(s: &str) -> Option<OptKind> {
    [
        OptKind::Parameterize,
        OptKind::Unroll,
        OptKind::Tile,
        OptKind::Fuse,
        OptKind::CachedWrite,
        OptKind::FloatOpt,
        OptKind::Channels,
        OptKind::Autorun,
        OptKind::Concurrent,
        OptKind::Quantize,
        OptKind::Vectorize,
        OptKind::Sparsify,
    ]
    .into_iter()
    .find(|o| o.abbrev() == s)
}

/// Draw a random scenario: mostly small random chains (wide structural
/// diversity, cheap forwards), sometimes LeNet-5 (a real network with
/// tanh/avg-pool f32 islands), over random pass subsets, modes and
/// precisions.
pub fn random_scenario(rng: &mut Rng) -> Scenario {
    let net = if rng.below(10) < 7 {
        NetSpec::Chain { seed: rng.next_u64() }
    } else {
        NetSpec::Named("lenet5".into())
    };
    let mode = if rng.below(2) == 0 { Mode::Pipelined } else { Mode::Folded };
    let precision = match rng.below(3) {
        0 => Precision::F32,
        1 => Precision::F16,
        _ => Precision::Int8,
    };
    let opts: Vec<OptKind> = fuzz_opts().into_iter().filter(|_| rng.below(2) == 0).collect();
    Scenario { net, mode, precision, opts, frames: 2, frame: None, seed: rng.next_u64() }
}

/// Random layer chain (the `pass_properties` generator, re-homed where
/// both the property tests and the differ can reach it): convs
/// (optionally BN'd / activated), depthwise convs, bounded pools, then
/// flatten + dense. Always a valid graph.
pub fn random_chain(seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let channels = 1 + rng.below(3) as usize;
    let (mut b, x) = GraphBuilder::new(format!("chain{seed:x}"), Shape::Chw(channels, 16, 16));
    let mut cur = x;
    let mut pools = 0;
    let depth = 2 + rng.below(5);
    for i in 0..depth {
        cur = match rng.below(5) {
            0 | 1 => {
                let oc = 2 + rng.below(6) as usize;
                let bias = rng.below(2) == 0;
                let mut c = b.add(
                    format!("c{i}"),
                    Op::Conv2d {
                        out_channels: oc,
                        kernel: 3,
                        stride: 1,
                        padding: 1,
                        bias,
                        activation: Activation::None,
                    },
                    &[cur],
                );
                if rng.below(2) == 0 {
                    c = b.add(format!("c{i}.bn"), Op::BatchNorm, &[c]);
                }
                if rng.below(2) == 0 {
                    c = b.add(format!("c{i}.act"), Op::Activate(Activation::Relu), &[c]);
                }
                c
            }
            2 => {
                let bias = rng.below(2) == 0;
                let mut d = b.add(
                    format!("dw{i}"),
                    Op::DepthwiseConv2d {
                        kernel: 3,
                        stride: 1,
                        padding: 1,
                        bias,
                        activation: Activation::None,
                    },
                    &[cur],
                );
                if !bias && rng.below(2) == 0 {
                    d = b.add(format!("dw{i}.bn"), Op::BatchNorm, &[d]);
                }
                d
            }
            3 if pools < 2 => {
                pools += 1;
                b.add(format!("p{i}"), Op::MaxPool { kernel: 2, stride: 2, padding: 0 }, &[cur])
            }
            _ => b.add(format!("a{i}"), Op::Activate(Activation::Relu), &[cur]),
        };
    }
    let f = b.add("flat", Op::Flatten, &[cur]);
    let d = b.add(
        "fc",
        Op::Dense { out_features: 10, bias: true, activation: Activation::None },
        &[f],
    );
    b.finish(d)
}

/// Known-wrong program mutations for harness self-tests: prove a real
/// divergence is caught, localized and shrunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// Clear the first non-empty kernel epilogue (the kernel "forgets"
    /// its bias/activation) — a value mismatch *and* a structural
    /// violation.
    DropEpilogue,
    /// Re-widen the first narrowed kernel to f32 while the oracle stays
    /// quantized — a pure value mismatch localizing to that kernel.
    WidenPrecision,
}

impl Fault {
    pub fn name(&self) -> &'static str {
        match self {
            Fault::DropEpilogue => "drop-epilogue",
            Fault::WidenPrecision => "widen-precision",
        }
    }
}

/// Apply a fault to a built program. Returns the id of the mutated kernel
/// (`None` when no kernel qualifies — the scenario is then vacuous).
pub fn apply_fault(prog: &mut KernelProgram, fault: Fault) -> Option<usize> {
    match fault {
        Fault::DropEpilogue => {
            for k in &mut prog.kernels {
                if !k.nest.epilogue.is_empty() {
                    k.nest.epilogue.clear();
                    return Some(k.id);
                }
            }
            None
        }
        Fault::WidenPrecision => {
            for k in &mut prog.kernels {
                if k.nest.precision != Precision::F32 {
                    k.nest.precision = Precision::F32;
                    return Some(k.id);
                }
            }
            None
        }
    }
}

/// Build and verify one scenario.
pub fn run_scenario(s: &Scenario) -> VerifyReport {
    run_scenario_in(s, &mut Scratch::new())
}

/// [`run_scenario`] over a caller-owned [`Scratch`] arena: the fuzz loop
/// hands one arena across all its scenarios, so same-shaped networks
/// (chains share the 16×16 input, LeNet recurs) reuse each other's
/// buffers instead of re-allocating per scenario. This is what bought the
/// scenario-count headroom in CI's `verify-fuzz` job (120 → 400 within
/// the same wall-clock budget).
pub fn run_scenario_in(s: &Scenario, scratch: &mut Scratch) -> VerifyReport {
    run_scenario_with_fault_in(s, None, scratch)
}

/// [`run_scenario`] with an optional injected fault (self-tests).
pub fn run_scenario_with_fault(s: &Scenario, fault: Option<Fault>) -> VerifyReport {
    run_scenario_with_fault_in(s, fault, &mut Scratch::new())
}

/// [`run_scenario_with_fault`] over a caller-owned [`Scratch`] arena.
pub fn run_scenario_with_fault_in(
    s: &Scenario,
    fault: Option<Fault>,
    scratch: &mut Scratch,
) -> VerifyReport {
    let g = s.graph();
    let cfg = s.cfg();
    let plan = default_factors(&g);
    let mut built = build_with_passes(&g, s.mode, &cfg, &plan);
    if let Some(f) = fault {
        apply_fault(&mut built.program, f);
    }
    let all = frames_for(&g, s.frames, s.seed);
    let frames: Vec<Vec<f32>> = match s.frame {
        Some(i) => vec![all[i.min(all.len() - 1)].clone()],
        None => all,
    };
    verify_program_in(
        &g,
        &built.program,
        s.precision,
        built.trace.required_equivalence(),
        &frames,
        &VerifyOptions::default(),
        scratch,
    )
}

/// Greedily shrink a failing scenario to a minimal reproducer: pin the
/// single failing frame, drop every droppable pass, widen the precision
/// to f32 when the failure survives it. The result still fails (and the
/// original is returned unchanged if it never failed).
pub fn shrink(s: &Scenario, fault: Option<Fault>) -> Scenario {
    // One arena across every shrink probe — the candidates are all
    // variations of one network family, so the buffers recycle.
    let mut scratch = Scratch::new();
    let mut fails =
        |sc: &Scenario| !run_scenario_with_fault_in(sc, fault, &mut scratch).passed;
    let mut cur = s.clone();
    if !fails(&cur) {
        return cur;
    }
    // 1. One frame is enough.
    if cur.frame.is_none() {
        for i in 0..cur.frames.max(1) {
            let mut t = cur.clone();
            t.frame = Some(i);
            if fails(&t) {
                cur = t;
                break;
            }
        }
    }
    // 2. Drop passes to a fixpoint.
    loop {
        let mut shrunk = false;
        for i in 0..cur.opts.len() {
            let mut t = cur.clone();
            t.opts.remove(i);
            if fails(&t) {
                cur = t;
                shrunk = true;
                break;
            }
        }
        if !shrunk {
            break;
        }
    }
    // 3. Prefer the plain f32 datapath when the failure survives it.
    if cur.precision != Precision::F32 {
        let mut t = cur.clone();
        t.precision = Precision::F32;
        if fails(&t) {
            cur = t;
        }
    }
    cur
}

/// A shrunk counterexample plus everything needed to replay it.
#[derive(Debug, Clone)]
pub struct Reproducer {
    pub original: Scenario,
    pub shrunk: Scenario,
    pub fault: Option<Fault>,
    /// `VerifyReport::summary` of the shrunk failure.
    pub summary: String,
    /// Shell line that replays the shrunk scenario.
    pub replay: String,
}

/// Build the reproducer for a failing scenario (runs the shrinker).
pub fn reproduce(original: &Scenario, fault: Option<Fault>) -> Reproducer {
    let shrunk = shrink(original, fault);
    let report = run_scenario_with_fault(&shrunk, fault);
    Reproducer {
        original: original.clone(),
        shrunk: shrunk.clone(),
        fault,
        summary: report.summary(),
        replay: format!(
            "VERIFY_REPRO_PATH={} cargo test --test differential replay_reproducer -- --nocapture",
            repro_path().display()
        ),
    }
}

impl Reproducer {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("original".into(), self.original.to_json());
        m.insert("shrunk".into(), self.shrunk.to_json());
        m.insert(
            "fault".into(),
            match self.fault {
                Some(f) => Json::Str(f.name().into()),
                None => Json::Null,
            },
        );
        m.insert("summary".into(), Json::Str(self.summary.clone()));
        m.insert("replay".into(), Json::Str(self.replay.clone()));
        Json::Obj(m)
    }
}

/// Where reproducers are written: `$VERIFY_REPRO_PATH` or
/// `target/verify-repro.json` (uploaded by the CI `verify-fuzz` job).
pub fn repro_path() -> PathBuf {
    std::env::var("VERIFY_REPRO_PATH")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/verify-repro.json"))
}

/// Serialize a reproducer to [`repro_path`], creating parent directories.
pub fn write_reproducer(r: &Reproducer) -> std::io::Result<PathBuf> {
    let path = repro_path();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&path, r.to_json().to_string())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_json_roundtrips() {
        let s = Scenario {
            net: NetSpec::Chain { seed: 0xBEEF },
            mode: Mode::Folded,
            precision: Precision::Int8,
            opts: vec![OptKind::Fuse, OptKind::Parameterize],
            frames: 4,
            frame: Some(2),
            seed: 0x1234,
        };
        let j = s.to_json();
        let back = Scenario::from_json(&j).expect("roundtrip parses");
        assert_eq!(s, back);
        let named = Scenario { net: NetSpec::Named("lenet5".into()), frame: None, ..s };
        assert_eq!(Scenario::from_json(&named.to_json()), Some(named.clone()));
        // Unknown pass abbreviations are rejected, not silently dropped —
        // a version-skewed reproducer must not replay a weaker subset.
        if let Json::Obj(mut m) = named.to_json() {
            m.insert("opts".into(), Json::Arr(vec![Json::Str("ZZ".into())]));
            assert_eq!(Scenario::from_json(&Json::Obj(m)), None);
        } else {
            unreachable!("scenario json is an object");
        }
    }

    #[test]
    fn random_chains_are_deterministic_and_valid() {
        for seed in [1u64, 7, 99, 0xABCD] {
            let a = random_chain(seed);
            let b = random_chain(seed);
            a.validate().expect("generator builds valid graphs");
            assert_eq!(a.nodes.len(), b.nodes.len());
            assert_eq!(a.name, b.name);
        }
    }

    #[test]
    fn canonical_scenarios_pass() {
        // The full optimized subset, both modes, all precisions, on a
        // representative chain.
        for mode in [Mode::Pipelined, Mode::Folded] {
            for p in Precision::all() {
                let s = Scenario {
                    net: NetSpec::Chain { seed: 42 },
                    mode,
                    precision: p,
                    opts: fuzz_opts(),
                    frames: 2,
                    frame: None,
                    seed: 5,
                };
                let rep = run_scenario(&s);
                assert!(rep.passed, "{}: {}", s.describe(), rep.summary());
            }
        }
    }

    #[test]
    fn injected_fault_fails_and_shrinks() {
        let s = Scenario {
            net: NetSpec::Named("lenet5".into()),
            mode: Mode::Pipelined,
            precision: Precision::Int8,
            opts: fuzz_opts(),
            frames: 3,
            frame: None,
            seed: 21,
        };
        let fault = Some(Fault::DropEpilogue);
        assert!(!run_scenario_with_fault(&s, fault).passed);
        let shrunk = shrink(&s, fault);
        assert!(shrunk.frame.is_some(), "shrinker pins one frame");
        assert!(shrunk.opts.is_empty(), "fault survives every pass removal: {shrunk:?}");
        assert_eq!(shrunk.precision, Precision::F32, "fault survives widening");
        assert!(!run_scenario_with_fault(&shrunk, fault).passed, "shrunk case still fails");
    }
}
