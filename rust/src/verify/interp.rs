//! Functional interpreter for lowered [`KernelProgram`]s.
//!
//! Executes the *compiled* dataflow — kernels firing in channel order,
//! per-dispatch layers of parameterized kernels, fused epilogue chains,
//! and the f32/fp16/int8 datapaths the schedule selected — so the program
//! can be diffed against the graph-level oracle
//! ([`crate::quant::Executor`]). The interpreter deliberately derives
//! *what* to compute from the program, not the graph:
//!
//! * dispatch order comes from the channel topology (pipelined) or the
//!   per-layer work order (folded);
//! * each kernel's datapath precision comes from its scheduled
//!   [`LoopNest::precision`], not from the verify request;
//! * bias/activation intrinsics come from the kernel's recorded
//!   [`Epilogue`] entries — a pass that drops or reorders them produces a
//!   wrong value, which is exactly what the differential harness exists
//!   to catch;
//! * absorbed BatchNorm/activation chains are resolved per dispatched
//!   layer (parameterized kernels apply them as runtime parameters, so
//!   member layers of one group may carry different chains).
//!
//! Elementary op arithmetic mirrors the oracle's evaluation order
//! (accumulator widths, loop order, fp16 rounding points) so that int8
//! programs agree **bit-exactly** and float programs agree within the
//! documented tolerance (`docs/VERIFICATION.md`).
//!
//! [`LoopNest::precision`]: crate::texpr::LoopNest

use std::collections::BTreeMap;

use crate::codegen::{Kernel, KernelProgram};
use crate::graph::{Activation, Graph, NodeId, Op};
use crate::pass::schedule::node_kernel_map;
use crate::quant::calibrate::CalibrationTable;
// The scheduling-invariant op semantics (activation, pooling, channel
// grouping) are shared with the oracle on purpose: no pass has value
// freedom there, and a one-sided change would turn every differential
// run into a spurious failure.
use crate::quant::exec::{
    activate, channels_of, pool, quantize_operands, Executor, QuantizedOperands,
};
use crate::quant::scheme::{f16_round, QParams, QScheme};
use crate::texpr::{Epilogue, Precision};

/// One interpreted frame: the logits plus every intermediate the program
/// produced (indexed by graph node id) for mismatch localization.
#[derive(Debug, Clone)]
pub struct FrameRun {
    pub logits: Vec<f32>,
    pub per_node: Vec<Option<Vec<f32>>>,
}

/// Functional interpreter over one (graph, program) pair. Construction
/// performs all structural validation once ([`Interpreter::structure`]);
/// [`Interpreter::run_frame`] then executes frames.
pub struct Interpreter<'a> {
    graph: &'a Graph,
    program: &'a KernelProgram,
    oracle: &'a Executor<'a>,
    table: &'a CalibrationTable,
    scheme: QScheme,
    /// Datapath precision the oracle runs at (`F32` = plain forward).
    precision: Precision,
    map: BTreeMap<NodeId, usize>,
    /// Absorbed BN/activation chain of every kernel-owned node.
    chains: BTreeMap<NodeId, Vec<NodeId>>,
    /// (kernel, node) dispatch order.
    dispatch: Vec<(usize, NodeId)>,
    violations: Vec<String>,
}

impl<'a> Interpreter<'a> {
    pub fn new(
        graph: &'a Graph,
        program: &'a KernelProgram,
        oracle: &'a Executor<'a>,
        table: &'a CalibrationTable,
        scheme: QScheme,
        precision: Precision,
    ) -> Interpreter<'a> {
        let map = node_kernel_map(program);
        let consumers = graph.consumers();
        let mut chains = BTreeMap::new();
        for &nid in map.keys() {
            chains.insert(nid, absorbed_chain(graph, &map, &consumers, nid));
        }
        let mut itp = Interpreter {
            graph,
            program,
            oracle,
            table,
            scheme,
            precision,
            map,
            chains,
            dispatch: Vec::new(),
            violations: Vec::new(),
        };
        itp.check_structure();
        let dispatch = itp.build_dispatch();
        itp.dispatch = dispatch;
        itp
    }

    /// Structural findings (empty = the program is well-formed). Each
    /// entry names one violated invariant; any entry fails verification.
    pub fn structure(&self) -> &[String] {
        &self.violations
    }

    // -- structural validation ---------------------------------------------

    fn check_structure(&mut self) {
        // Structural validation is owned by the static analyzer
        // ([`crate::analysis`]) — autorun legality, channel wiring/depth,
        // token balance, lost nodes, epilogue/absorbed divergence and the
        // §IV-H stash-capacity rule are a single implementation there.
        // The interpreter keeps its legacy message-string surface for
        // verify reports; cycle detection stays in `build_dispatch` (which
        // also needs the fallback dispatch order) and is excluded from the
        // delegated set to avoid double-reporting.
        self.violations = crate::analysis::structural_violations(self.graph, self.program)
            .into_iter()
            .map(|d| d.message)
            .collect();
    }

    // -- dispatch ----------------------------------------------------------

    /// Topological position of every node (for ordering layer dispatches).
    fn topo_pos(&self) -> Vec<usize> {
        let mut pos = vec![0usize; self.graph.nodes.len()];
        for (i, n) in self.graph.topo().enumerate() {
            pos[n.id] = i;
        }
        pos
    }

    /// (kernel, layer) dispatch order: channel-driven (Kahn over the FIFO
    /// topology) when the program is channelized, per-layer topological
    /// order otherwise. A cyclic channel graph is recorded as a violation
    /// and falls back to topological dispatch.
    fn build_dispatch(&mut self) -> Vec<(usize, NodeId)> {
        let pos = self.topo_pos();
        let topo_dispatch = |map: &BTreeMap<NodeId, usize>| -> Vec<(usize, NodeId)> {
            let mut d: Vec<(usize, NodeId)> =
                map.iter().map(|(&nid, &k)| (k, nid)).collect();
            d.sort_by_key(|&(_, nid)| pos[nid]);
            d
        };
        if self.program.channels.is_empty() {
            return topo_dispatch(&self.map);
        }
        let n = self.program.kernels.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for ch in &self.program.channels {
            if ch.from_kernel < n && ch.to_kernel < n && ch.from_kernel != ch.to_kernel {
                adj[ch.from_kernel].push(ch.to_kernel);
                indeg[ch.to_kernel] += 1;
            }
        }
        let mut order: Vec<usize> = Vec::with_capacity(n);
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        while let Some(&next) = ready.iter().min() {
            ready.retain(|&i| i != next);
            order.push(next);
            for &to in &adj[next] {
                indeg[to] -= 1;
                if indeg[to] == 0 {
                    ready.push(to);
                }
            }
        }
        if order.len() != n {
            self.violations.push("channel topology is cyclic — kernels can never fire".into());
            return topo_dispatch(&self.map);
        }
        let mut dispatch = Vec::new();
        for k in order {
            let mut layers = self.program.kernels[k].layers.clone();
            layers.sort_by_key(|&nid| pos[nid]);
            for nid in layers {
                dispatch.push((k, nid));
            }
        }
        dispatch
    }

    // -- execution ---------------------------------------------------------

    /// Execute one frame through the program. `Err` means the program's
    /// dataflow could not produce a result (e.g. a kernel fired before its
    /// producer under a wrong channel topology).
    pub fn run_frame(&self, frame: &[f32]) -> Result<FrameRun, String> {
        let g = self.graph;
        if frame.len() != g.nodes[g.input].shape.elems() {
            return Err(format!(
                "frame has {} elements, the graph input wants {}",
                frame.len(),
                g.nodes[g.input].shape.elems()
            ));
        }
        let mut values: Vec<Option<Vec<f32>>> = vec![None; g.nodes.len()];
        values[g.input] = Some(frame.to_vec());
        for &(k, nid) in &self.dispatch {
            self.fire(&self.program.kernels[k], nid, &mut values)?;
        }
        // The graph output may itself be a layout node over the last
        // kernel's result.
        self.ensure_value(g.output, &mut values)?;
        let logits = values[g.output]
            .clone()
            .ok_or_else(|| "program produced no value for the graph output".to_string())?;
        Ok(FrameRun { logits, per_node: values })
    }

    /// Materialize `id`'s value when it is a layout node over an already
    /// computed producer.
    fn ensure_value(&self, id: NodeId, values: &mut Vec<Option<Vec<f32>>>) -> Result<(), String> {
        if values[id].is_some() {
            return Ok(());
        }
        let n = &self.graph.nodes[id];
        match n.op {
            Op::Flatten | Op::Transform => {
                let src = n.inputs[0];
                self.ensure_value(src, values)?;
                values[id] = values[src].clone();
                Ok(())
            }
            _ => Err(format!(
                "kernel fired before its input {} ({}) was produced — dataflow order diverges \
                 from the graph",
                n.name,
                n.op.mnemonic()
            )),
        }
    }

    fn input_value(
        &self,
        id: NodeId,
        values: &mut Vec<Option<Vec<f32>>>,
    ) -> Result<Vec<f32>, String> {
        self.ensure_value(id, values)?;
        Ok(values[id].clone().expect("ensured"))
    }

    /// Fire kernel `k` for layer `nid`: compute the node at the kernel's
    /// scheduled precision, apply the epilogue intrinsics the kernel
    /// recorded, then the layer's absorbed BN/activation chain.
    fn fire(
        &self,
        k: &Kernel,
        nid: NodeId,
        values: &mut Vec<Option<Vec<f32>>>,
    ) -> Result<(), String> {
        let g = self.graph;
        let n = &g.nodes[nid];
        let chain = self.chains.get(&nid).cloned().unwrap_or_default();
        // Intrinsic epilogue entries for this dispatch: the kernel's
        // recorded entries for its representative layer (minus the
        // absorbed suffix); runtime parameters for group members.
        let intrinsic: Vec<Epilogue> = if nid == k.layers[0] {
            let cut = k.nest.epilogue.len().saturating_sub(chain.len());
            k.nest.epilogue[..cut].to_vec()
        } else {
            expected_intrinsic(&n.op)
        };
        let out = match &n.op {
            Op::Conv2d { kernel, stride, padding, .. } => {
                let x = self.input_value(n.inputs[0], values)?;
                self.conv(k, nid, &x, *kernel, *stride, *padding, false, &intrinsic)
            }
            Op::DepthwiseConv2d { kernel, stride, padding, .. } => {
                let x = self.input_value(n.inputs[0], values)?;
                self.conv(k, nid, &x, *kernel, *stride, *padding, true, &intrinsic)
            }
            Op::Dense { .. } => {
                let x = self.input_value(n.inputs[0], values)?;
                self.dense(k, nid, &x, &intrinsic)
            }
            Op::BatchNorm => {
                let x = self.input_value(n.inputs[0], values)?;
                self.batchnorm(nid, &x)
            }
            Op::Activate(a) => {
                let x = self.input_value(n.inputs[0], values)?;
                x.iter().map(|&v| activate(v, *a)).collect()
            }
            Op::MaxPool { kernel, stride, padding } => {
                let x = self.input_value(n.inputs[0], values)?;
                pool(&x, &g.nodes[n.inputs[0]].shape, &n.shape, *kernel, *stride, *padding, true)
            }
            Op::AvgPool { kernel, stride, padding } => {
                let x = self.input_value(n.inputs[0], values)?;
                pool(&x, &g.nodes[n.inputs[0]].shape, &n.shape, *kernel, *stride, *padding, false)
            }
            Op::GlobalAvgPool => {
                let x = self.input_value(n.inputs[0], values)?;
                let (c, h, w) = g.nodes[n.inputs[0]].shape.chw().expect("gap input CHW");
                (0..c)
                    .map(|ch| x[ch * h * w..(ch + 1) * h * w].iter().sum::<f32>() / (h * w) as f32)
                    .collect()
            }
            Op::Add => {
                let a = self.input_value(n.inputs[0], values)?;
                let b = self.input_value(n.inputs[1], values)?;
                a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
            }
            Op::Softmax => {
                let x = self.input_value(n.inputs[0], values)?;
                let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let e: Vec<f32> = x.iter().map(|v| (v - m).exp()).collect();
                let s: f32 = e.iter().sum();
                e.into_iter().map(|v| v / s).collect()
            }
            Op::Quantize { precision } => {
                let src = n.inputs[0];
                let x = self.input_value(src, values)?;
                if self.precision != Precision::F32 && *precision == Precision::Int8 {
                    let qp = QParams::per_tensor(self.table.activation(src), Precision::Int8);
                    x.iter().map(|&v| qp.roundtrip(v as f64, 0) as f32).collect()
                } else if *precision == Precision::F16 {
                    x.iter().map(|&v| f16_round(v)).collect()
                } else {
                    x
                }
            }
            Op::Dequantize { .. } => self.input_value(n.inputs[0], values)?,
            Op::Input | Op::Flatten | Op::Transform => {
                return Err(format!("layout node {} owns a kernel", n.name));
            }
        };
        values[nid] = Some(out);
        // Absorbed chain: runtime-parameterized epilogue per dispatch.
        for &a in &chain {
            let prev = values[self.graph.nodes[a].inputs[0]]
                .clone()
                .ok_or_else(|| format!("absorbed node {a} has no input value"))?;
            let out = match self.graph.nodes[a].op {
                Op::BatchNorm => self.batchnorm(a, &prev),
                Op::Activate(act) => prev.iter().map(|&v| activate(v, act)).collect(),
                _ => prev,
            };
            values[a] = Some(out);
        }
        Ok(())
    }

    // -- datapaths (mirroring the oracle's evaluation order) ---------------

    /// Quantized operands for a compute dispatch, iff the *kernel* was
    /// scheduled at int8 (the verify request only enables the grid).
    /// Operand preparation itself is the oracle's
    /// ([`crate::quant::exec::quantize_operands`]) — pass-invariant
    /// semantics are shared, only the *decision* to quantize is read off
    /// the program.
    fn int8_operands(&self, k: &Kernel, nid: NodeId, x: &[f32]) -> Option<QuantizedOperands> {
        if k.nest.precision != Precision::Int8 || self.precision != Precision::Int8 {
            return None;
        }
        let src = self.graph.nodes[nid].inputs[0];
        Some(quantize_operands(
            x,
            self.oracle.weights(nid),
            self.table.activation(src),
            &self.table.weight_ranges(nid),
            self.scheme,
        ))
    }

    fn f16_datapath(&self, k: &Kernel) -> bool {
        k.nest.precision == Precision::F16 && self.precision == Precision::F16
    }

    #[allow(clippy::too_many_arguments)]
    fn conv(
        &self,
        kern: &Kernel,
        nid: NodeId,
        x: &[f32],
        k: usize,
        stride: usize,
        padding: usize,
        depthwise: bool,
        intrinsic: &[Epilogue],
    ) -> Vec<f32> {
        let g = self.graph;
        let n = &g.nodes[nid];
        let (cin, h, w) = g.nodes[n.inputs[0]].shape.chw().expect("conv input CHW");
        let (oc, oh, ow) = n.shape.chw().expect("conv output CHW");
        let weights = self.oracle.weights(nid);
        let bias = self.oracle.bias(nid);
        let int8 = self.int8_operands(kern, nid, x);
        let f16 = int8.is_none() && self.f16_datapath(kern);
        let rx: Vec<f32> =
            if f16 { x.iter().map(|&v| f16_round(v)).collect() } else { Vec::new() };
        let mut out = vec![0f32; oc * oh * ow];
        for o in 0..oc {
            let w_base = if depthwise { o * k * k } else { o * cin * k * k };
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc_f = 0f64;
                    let mut acc_i = 0i64;
                    let crange = if depthwise { o..o + 1 } else { 0..cin };
                    for c in crange {
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = (oy * stride + ky) as isize - padding as isize;
                                let ix = (ox * stride + kx) as isize - padding as isize;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                                    continue;
                                }
                                let xi = c * h * w + iy as usize * w + ix as usize;
                                let wi = if depthwise {
                                    w_base + ky * k + kx
                                } else {
                                    w_base + (c * k + ky) * k + kx
                                };
                                if let Some(q8) = &int8 {
                                    acc_i += q8.qx[xi] as i64 * q8.qw[wi] as i64;
                                } else if f16 {
                                    acc_f += (rx[xi] * f16_round(weights[wi])) as f64;
                                } else {
                                    acc_f += (x[xi] * weights[wi]) as f64;
                                }
                            }
                        }
                    }
                    let v = match &int8 {
                        Some(q8) => (acc_i as f64 * q8.sx * q8.wq.scale(o)) as f32,
                        None => acc_f as f32,
                    };
                    out[(o * oh + oy) * ow + ox] =
                        apply_conv_epilogue(v, o, bias, intrinsic, f16);
                }
            }
        }
        out
    }

    fn dense(&self, kern: &Kernel, nid: NodeId, x: &[f32], intrinsic: &[Epilogue]) -> Vec<f32> {
        let weights = self.oracle.weights(nid);
        let bias = self.oracle.bias(nid);
        let cin = x.len();
        let oc = bias.len().max(weights.len() / cin.max(1));
        let int8 = self.int8_operands(kern, nid, x);
        let f16 = int8.is_none() && self.f16_datapath(kern);
        (0..oc)
            .map(|o| {
                let row = &weights[o * cin..(o + 1) * cin];
                let mut v = match &int8 {
                    Some(q8) => {
                        let qrow = &q8.qw[o * cin..(o + 1) * cin];
                        let acc: i64 =
                            q8.qx.iter().zip(qrow).map(|(&a, &b)| a as i64 * b as i64).sum();
                        (acc as f64 * q8.sx * q8.wq.scale(o)) as f32
                    }
                    _ if f16 => f16_round(
                        x.iter()
                            .map(|&v| f16_round(v))
                            .zip(row)
                            .map(|(a, &b)| a * f16_round(b))
                            .sum::<f32>(),
                    ),
                    _ => x.iter().zip(row).map(|(&a, &b)| a * b).sum::<f32>(),
                };
                // The oracle's dense fp16 path rounds *before* the bias
                // (conv rounds after) — mirrored, and documented in
                // docs/VERIFICATION.md.
                for e in intrinsic {
                    match e {
                        Epilogue::BiasAdd => v += bias[o],
                        Epilogue::Activation(a) => v = activate(v, *a),
                        Epilogue::BatchNormFold => {}
                    }
                }
                v
            })
            .collect()
    }

    fn batchnorm(&self, nid: NodeId, x: &[f32]) -> Vec<f32> {
        let w = self.oracle.weights(nid);
        let b = self.oracle.bias(nid);
        let c = channels_of(&self.graph.nodes[nid].shape);
        let per = x.len() / c.max(1);
        x.iter()
            .enumerate()
            .map(|(i, &v)| v * w[i / per.max(1)] + b[i / per.max(1)])
            .collect()
    }

}

/// Conv-family epilogue at one output element, honoring the kernel's
/// recorded intrinsics. fp16 datapaths round once after the bias and
/// before the first activation (the oracle's evaluation order).
fn apply_conv_epilogue(
    mut v: f32,
    o: usize,
    bias: &[f32],
    intrinsic: &[Epilogue],
    f16: bool,
) -> f32 {
    let mut rounded = !f16;
    for e in intrinsic {
        match e {
            Epilogue::BiasAdd => v += bias[o],
            Epilogue::Activation(a) => {
                if !rounded {
                    v = f16_round(v);
                    rounded = true;
                }
                v = activate(v, *a);
            }
            Epilogue::BatchNormFold => {}
        }
    }
    if !rounded {
        v = f16_round(v);
    }
    v
}

/// Intrinsic epilogue a node's op attributes imply (what `texpr::lower`
/// seeds the nest with).
pub fn expected_intrinsic(op: &Op) -> Vec<Epilogue> {
    let mut e = Vec::new();
    match op {
        Op::Conv2d { bias, activation, .. }
        | Op::DepthwiseConv2d { bias, activation, .. }
        | Op::Dense { bias, activation, .. } => {
            if *bias {
                e.push(Epilogue::BiasAdd);
            }
            if *activation != Activation::None {
                e.push(Epilogue::Activation(*activation));
            }
        }
        _ => {}
    }
    e
}

/// The BN/activation nodes absorbed into `host`'s kernel, in absorption
/// order: follow single-consumer edges to epilogue ops that own no kernel.
pub fn absorbed_chain(
    graph: &Graph,
    map: &BTreeMap<NodeId, usize>,
    consumers: &[Vec<NodeId>],
    host: NodeId,
) -> Vec<NodeId> {
    let mut chain = Vec::new();
    let mut cur = host;
    loop {
        if consumers[cur].len() != 1 {
            break;
        }
        let next = consumers[cur][0];
        let absorbable = !map.contains_key(&next)
            && matches!(graph.nodes[next].op, Op::BatchNorm | Op::Activate(_));
        if !absorbable {
            break;
        }
        chain.push(next);
        cur = next;
    }
    chain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::patterns::{build_with_passes, default_factors, OptConfig};
    use crate::flow::Mode;
    use crate::graph::models;
    use crate::quant::calibrate::{calibrate_analytic, Calibrator};

    fn interp_setup(
        mode: Mode,
        cfg: &OptConfig,
    ) -> (Graph, crate::codegen::KernelProgram) {
        let g = models::lenet5();
        let plan = default_factors(&g);
        let built = build_with_passes(&g, mode, cfg, &plan);
        (g, built.program)
    }

    #[test]
    fn well_formed_programs_have_no_violations() {
        for mode in [Mode::Pipelined, Mode::Folded] {
            for cfg in [OptConfig::base(), OptConfig::optimized()] {
                let (g, prog) = interp_setup(mode, &cfg);
                let exec = Executor::new(&g);
                let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
                let itp = Interpreter::new(
                    &g,
                    &prog,
                    &exec,
                    &table,
                    QScheme::PerChannel,
                    Precision::F32,
                );
                assert_eq!(itp.structure(), &[] as &[String], "{mode:?} {cfg:?}");
            }
        }
    }

    #[test]
    fn interpreter_matches_oracle_on_lenet_f32() {
        let (g, prog) = interp_setup(Mode::Pipelined, &OptConfig::optimized());
        let exec = Executor::new(&g);
        let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
        let itp =
            Interpreter::new(&g, &prog, &exec, &table, QScheme::PerChannel, Precision::F32);
        let data = crate::data::mnist_like(2, 32, 7);
        for i in 0..2 {
            let want = exec.forward(data.frame(i), |_, _| {});
            let got = itp.run_frame(data.frame(i)).unwrap().logits;
            assert_eq!(want.len(), got.len());
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a, b, "f32 interpretation should mirror the oracle bitwise");
            }
        }
    }

    #[test]
    fn structural_checks_flag_broken_programs() {
        let (g, mut prog) = interp_setup(Mode::Pipelined, &OptConfig::optimized());
        // Drop the first kernel's epilogue: the chain check must fire.
        let victim = prog
            .kernels
            .iter_mut()
            .find(|k| !k.nest.epilogue.is_empty())
            .expect("lenet has epilogues");
        victim.nest.epilogue.clear();
        let exec = Executor::new(&g);
        let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
        let itp =
            Interpreter::new(&g, &prog, &exec, &table, QScheme::PerChannel, Precision::F32);
        assert!(
            itp.structure().iter().any(|v| v.contains("epilogue")),
            "{:?}",
            itp.structure()
        );
    }

    #[test]
    fn channel_mis_wiring_is_flagged() {
        let (g, mut prog) = interp_setup(Mode::Pipelined, &OptConfig::optimized());
        assert!(!prog.channels.is_empty());
        // Re-point one channel at its own producer: now one graph edge has
        // no channel and one channel matches no edge.
        let last = prog.kernels.len() - 1;
        prog.channels[0].to_kernel = if prog.channels[0].to_kernel == last { 0 } else { last };
        let exec = Executor::new(&g);
        let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
        let itp =
            Interpreter::new(&g, &prog, &exec, &table, QScheme::PerChannel, Precision::F32);
        assert!(
            itp.structure().iter().any(|v| v.contains("channel")),
            "{:?}",
            itp.structure()
        );
    }
}
