//! Functional interpreter for lowered [`KernelProgram`]s.
//!
//! Executes the *compiled* dataflow — kernels firing in channel order,
//! per-dispatch layers of parameterized kernels, fused epilogue chains,
//! and the f32/fp16/int8 datapaths the schedule selected — so the program
//! can be diffed against the graph-level oracle
//! ([`crate::quant::Executor`]). The interpreter deliberately derives
//! *what* to compute from the program, not the graph:
//!
//! * dispatch order comes from the channel topology (pipelined) or the
//!   per-layer work order (folded);
//! * each kernel's datapath precision comes from its scheduled
//!   [`LoopNest::precision`], not from the verify request;
//! * bias/activation intrinsics come from the kernel's recorded
//!   [`Epilogue`] entries — a pass that drops or reorders them produces a
//!   wrong value, which is exactly what the differential harness exists
//!   to catch;
//! * absorbed BatchNorm/activation chains are resolved per dispatched
//!   layer (parameterized kernels apply them as runtime parameters, so
//!   member layers of one group may carry different chains).
//!
//! Elementary op arithmetic mirrors the oracle's evaluation order
//! (accumulator widths, loop order, fp16 rounding points) so that int8
//! programs agree **bit-exactly** and float programs agree within the
//! documented tolerance (`docs/VERIFICATION.md`).
//!
//! [`LoopNest::precision`]: crate::texpr::LoopNest

use std::collections::BTreeMap;

use crate::codegen::KernelProgram;
use crate::graph::{Activation, Graph, NodeId, Op};
use crate::pass::schedule::node_kernel_map;
use crate::quant::calibrate::CalibrationTable;
// The scheduling-invariant op semantics (activation, pooling, channel
// grouping) are shared with the oracle on purpose: no pass has value
// freedom there, and a one-sided change would turn every differential
// run into a spurious failure. The kernel *cores* (`conv_core_into`,
// `dense_core_into`, …) are shared too — the interpreter differs from the
// oracle only in what it derives from the program (dispatch order,
// precision, recorded epilogues), never in arithmetic.
use crate::quant::exec::{
    activate, batchnorm_into, channels_of, conv_core_into, dense_core_into, f16_round_into,
    int8_prep, pool_into, quantize_into, ConvGeom, Executor, Int8Prep, MatOperands,
};
use crate::quant::scheme::{f16_round, QParams, QScheme};
use crate::texpr::{Epilogue, Precision};
use crate::util::scratch::Scratch;

/// One interpreted frame: the logits plus every intermediate the program
/// produced (indexed by graph node id) for mismatch localization.
#[derive(Debug, Clone)]
pub struct FrameRun {
    pub logits: Vec<f32>,
    pub per_node: Vec<Option<Vec<f32>>>,
}

/// Arena-owned per-frame execution state of one [`Interpreter`]. Check
/// one out with [`Interpreter::frame_state`], run any number of frames
/// through [`Interpreter::run_frame_into`], and hand the buffers back
/// with [`Interpreter::release_state`] — the steady-state loop performs
/// zero heap allocations.
pub struct FrameState {
    /// Per-node value buffer (length = the node's shape).
    pub(crate) values: Vec<Vec<f32>>,
    /// Which nodes have been produced this frame.
    pub(crate) produced: Vec<bool>,
    /// Shared int8 input-quantization scratch.
    qx: Vec<i32>,
    /// Shared fp16 input-rounding scratch.
    rx: Vec<f32>,
}

/// Frame-invariant prepared operands of one kernel-owned compute node
/// (the per-dispatch half — quantizing the activations — stays in
/// [`Interpreter::fire_into`]).
enum InterpPrep {
    None,
    /// Kernel scheduled at int8 and the verify request enables the grid.
    Int8(Int8Prep),
    /// fp16 datapath: weights pre-rounded onto the half grid.
    F16 { rw: Vec<f32> },
    /// Explicit int8 `Quantize` boundary under a quantized verify request.
    Grid(QParams),
}

/// Functional interpreter over one (graph, program) pair. Construction
/// performs all structural validation once ([`Interpreter::structure`])
/// and caches every frame-invariant decision — dispatch order, recorded
/// intrinsic epilogues, quantized/rounded weights; [`Interpreter::run_frame`]
/// (allocating) or [`Interpreter::run_frame_into`] (arena-backed,
/// allocation-free) then execute frames.
pub struct Interpreter<'a> {
    graph: &'a Graph,
    program: &'a KernelProgram,
    oracle: &'a Executor<'a>,
    table: &'a CalibrationTable,
    scheme: QScheme,
    /// Datapath precision the oracle runs at (`F32` = plain forward).
    precision: Precision,
    map: BTreeMap<NodeId, usize>,
    /// Absorbed BN/activation chain of every kernel-owned node.
    chains: BTreeMap<NodeId, Vec<NodeId>>,
    /// (kernel, node) dispatch order.
    dispatch: Vec<(usize, NodeId)>,
    /// Intrinsic epilogue of each dispatch (aligned with `dispatch`):
    /// the kernel's recorded entries for its representative layer, op-attr
    /// defaults for group members. Cached at construction — faults are
    /// applied to the program *before* the interpreter is built.
    intrinsics: Vec<Vec<Epilogue>>,
    /// Frame-invariant operand caches, indexed by node id.
    preps: Vec<InterpPrep>,
    violations: Vec<String>,
}

impl<'a> Interpreter<'a> {
    pub fn new(
        graph: &'a Graph,
        program: &'a KernelProgram,
        oracle: &'a Executor<'a>,
        table: &'a CalibrationTable,
        scheme: QScheme,
        precision: Precision,
    ) -> Interpreter<'a> {
        let map = node_kernel_map(program);
        let consumers = graph.consumers();
        let mut chains = BTreeMap::new();
        for &nid in map.keys() {
            chains.insert(nid, absorbed_chain(graph, &map, &consumers, nid));
        }
        let mut itp = Interpreter {
            graph,
            program,
            oracle,
            table,
            scheme,
            precision,
            map,
            chains,
            dispatch: Vec::new(),
            intrinsics: Vec::new(),
            preps: Vec::new(),
            violations: Vec::new(),
        };
        itp.check_structure();
        let dispatch = itp.build_dispatch();
        itp.dispatch = dispatch;
        itp.intrinsics = itp
            .dispatch
            .iter()
            .map(|&(k, nid)| {
                let kern = &program.kernels[k];
                let chain_len = itp.chains.get(&nid).map(Vec::len).unwrap_or(0);
                if nid == kern.layers[0] {
                    let cut = kern.nest.epilogue.len().saturating_sub(chain_len);
                    kern.nest.epilogue[..cut].to_vec()
                } else {
                    expected_intrinsic(&graph.nodes[nid].op)
                }
            })
            .collect();
        itp.preps = graph
            .nodes
            .iter()
            .map(|n| {
                let kprec = itp.map.get(&n.id).map(|&k| program.kernels[k].nest.precision);
                match &n.op {
                    Op::Conv2d { .. } | Op::DepthwiseConv2d { .. } | Op::Dense { .. } => {
                        match kprec {
                            Some(Precision::Int8) if precision == Precision::Int8 => {
                                InterpPrep::Int8(int8_prep(
                                    oracle.weights(n.id),
                                    table.activation(n.inputs[0]),
                                    &table.weight_ranges(n.id),
                                    scheme,
                                ))
                            }
                            Some(Precision::F16) if precision == Precision::F16 => {
                                InterpPrep::F16 {
                                    rw: oracle
                                        .weights(n.id)
                                        .iter()
                                        .map(|&w| f16_round(w))
                                        .collect(),
                                }
                            }
                            _ => InterpPrep::None,
                        }
                    }
                    Op::Quantize { precision: Precision::Int8 }
                        if precision != Precision::F32 =>
                    {
                        InterpPrep::Grid(QParams::per_tensor(
                            table.activation(n.inputs[0]),
                            Precision::Int8,
                        ))
                    }
                    _ => InterpPrep::None,
                }
            })
            .collect();
        itp
    }

    /// Structural findings (empty = the program is well-formed). Each
    /// entry names one violated invariant; any entry fails verification.
    pub fn structure(&self) -> &[String] {
        &self.violations
    }

    // -- structural validation ---------------------------------------------

    fn check_structure(&mut self) {
        // Structural validation is owned by the static analyzer
        // ([`crate::analysis`]) — autorun legality, channel wiring/depth,
        // token balance, lost nodes, epilogue/absorbed divergence and the
        // §IV-H stash-capacity rule are a single implementation there.
        // The interpreter keeps its legacy message-string surface for
        // verify reports; cycle detection stays in `build_dispatch` (which
        // also needs the fallback dispatch order) and is excluded from the
        // delegated set to avoid double-reporting.
        self.violations = crate::analysis::structural_violations(self.graph, self.program)
            .into_iter()
            .map(|d| d.message)
            .collect();
    }

    // -- dispatch ----------------------------------------------------------

    /// Topological position of every node (for ordering layer dispatches).
    fn topo_pos(&self) -> Vec<usize> {
        let mut pos = vec![0usize; self.graph.nodes.len()];
        for (i, n) in self.graph.topo().enumerate() {
            pos[n.id] = i;
        }
        pos
    }

    /// (kernel, layer) dispatch order: channel-driven (Kahn over the FIFO
    /// topology) when the program is channelized, per-layer topological
    /// order otherwise. A cyclic channel graph is recorded as a violation
    /// and falls back to topological dispatch.
    fn build_dispatch(&mut self) -> Vec<(usize, NodeId)> {
        let pos = self.topo_pos();
        let topo_dispatch = |map: &BTreeMap<NodeId, usize>| -> Vec<(usize, NodeId)> {
            let mut d: Vec<(usize, NodeId)> =
                map.iter().map(|(&nid, &k)| (k, nid)).collect();
            d.sort_by_key(|&(_, nid)| pos[nid]);
            d
        };
        if self.program.channels.is_empty() {
            return topo_dispatch(&self.map);
        }
        let n = self.program.kernels.len();
        let mut indeg = vec![0usize; n];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for ch in &self.program.channels {
            if ch.from_kernel < n && ch.to_kernel < n && ch.from_kernel != ch.to_kernel {
                adj[ch.from_kernel].push(ch.to_kernel);
                indeg[ch.to_kernel] += 1;
            }
        }
        let mut order: Vec<usize> = Vec::with_capacity(n);
        let mut ready: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        while let Some(&next) = ready.iter().min() {
            ready.retain(|&i| i != next);
            order.push(next);
            for &to in &adj[next] {
                indeg[to] -= 1;
                if indeg[to] == 0 {
                    ready.push(to);
                }
            }
        }
        if order.len() != n {
            self.violations.push("channel topology is cyclic — kernels can never fire".into());
            return topo_dispatch(&self.map);
        }
        let mut dispatch = Vec::new();
        for k in order {
            let mut layers = self.program.kernels[k].layers.clone();
            layers.sort_by_key(|&nid| pos[nid]);
            for nid in layers {
                dispatch.push((k, nid));
            }
        }
        dispatch
    }

    // -- execution ---------------------------------------------------------

    /// Check a [`FrameState`] for this interpreter out of `scratch`.
    pub fn frame_state(&self, scratch: &mut Scratch) -> FrameState {
        let g = self.graph;
        let values = g.nodes.iter().map(|n| scratch.take_f32(n.shape.elems())).collect();
        let max_elems = g.nodes.iter().map(|n| n.shape.elems()).max().unwrap_or(0);
        let need_qx = self.preps.iter().any(|p| matches!(p, InterpPrep::Int8(_)));
        let need_rx = self.preps.iter().any(|p| matches!(p, InterpPrep::F16 { .. }));
        FrameState {
            values,
            produced: vec![false; g.nodes.len()],
            qx: if need_qx { scratch.take_i32(max_elems) } else { Vec::new() },
            rx: if need_rx { scratch.take_f32(max_elems) } else { Vec::new() },
        }
    }

    /// Return a [`FrameState`]'s buffers to `scratch` for reuse.
    pub fn release_state(&self, st: FrameState, scratch: &mut Scratch) {
        for b in st.values {
            scratch.put_f32(b);
        }
        if !st.qx.is_empty() {
            scratch.put_i32(st.qx);
        }
        if !st.rx.is_empty() {
            scratch.put_f32(st.rx);
        }
    }

    /// The logits of the last frame run through `st`.
    pub fn logits<'s>(&self, st: &'s FrameState) -> &'s [f32] {
        &st.values[self.graph.output]
    }

    /// Execute one frame through the program (allocating convenience
    /// wrapper over [`Interpreter::run_frame_into`]). `Err` means the
    /// program's dataflow could not produce a result (e.g. a kernel fired
    /// before its producer under a wrong channel topology).
    pub fn run_frame(&self, frame: &[f32]) -> Result<FrameRun, String> {
        let mut scratch = Scratch::new();
        let mut st = self.frame_state(&mut scratch);
        let res = self.run_frame_into(frame, &mut st);
        res.map(|()| FrameRun {
            logits: st.values[self.graph.output].clone(),
            per_node: st
                .values
                .iter()
                .zip(&st.produced)
                .map(|(v, &p)| if p { Some(v.clone()) } else { None })
                .collect(),
        })
    }

    /// Execute one frame into an arena-owned [`FrameState`] — the
    /// steady-state entry point, zero heap allocations per call. Read the
    /// result through [`Interpreter::logits`] (or `st`'s per-node buffers
    /// via the crate-internal fields).
    pub fn run_frame_into(&self, frame: &[f32], st: &mut FrameState) -> Result<(), String> {
        let g = self.graph;
        if frame.len() != g.nodes[g.input].shape.elems() {
            return Err(format!(
                "frame has {} elements, the graph input wants {}",
                frame.len(),
                g.nodes[g.input].shape.elems()
            ));
        }
        for p in st.produced.iter_mut() {
            *p = false;
        }
        st.values[g.input].copy_from_slice(frame);
        st.produced[g.input] = true;
        // Hoisted out of the dispatch loop: the disabled-mode cost of
        // tracing is one atomic load per frame, not per dispatch
        // (`rust/tests/alloc_regression.rs` keeps this path at zero
        // allocations).
        if crate::obs::enabled() {
            let mut frame_span = crate::obs::span("verify", "interp_frame");
            frame_span.set_arg("network", g.name.as_str());
            frame_span.set_arg("dispatches", self.dispatch.len());
            let parent = frame_span.id();
            for (di, &(k, nid)) in self.dispatch.iter().enumerate() {
                let start = std::time::Instant::now();
                self.fire_into(nid, &self.intrinsics[di], st)?;
                crate::obs::span_at(
                    "verify",
                    &g.nodes[nid].name,
                    parent,
                    start,
                    std::time::Instant::now(),
                    vec![("kernel", crate::obs::ArgValue::Num(k as f64))],
                );
            }
        } else {
            for (di, &(_, nid)) in self.dispatch.iter().enumerate() {
                self.fire_into(nid, &self.intrinsics[di], st)?;
            }
        }
        // The graph output may itself be a layout node over the last
        // kernel's result.
        self.ensure_value(g.output, st)?;
        if !st.produced[g.output] {
            return Err("program produced no value for the graph output".to_string());
        }
        Ok(())
    }

    /// Materialize `id`'s value when it is a layout node over an already
    /// computed producer.
    fn ensure_value(&self, id: NodeId, st: &mut FrameState) -> Result<(), String> {
        if st.produced[id] {
            return Ok(());
        }
        let n = &self.graph.nodes[id];
        match n.op {
            Op::Flatten | Op::Transform => {
                let src = n.inputs[0];
                self.ensure_value(src, st)?;
                let mut buf = std::mem::take(&mut st.values[id]);
                buf.copy_from_slice(&st.values[src]);
                st.values[id] = buf;
                st.produced[id] = true;
                Ok(())
            }
            _ => Err(format!(
                "kernel fired before its input {} ({}) was produced — dataflow order diverges \
                 from the graph",
                n.name,
                n.op.mnemonic()
            )),
        }
    }

    /// Fire the kernel dispatch for layer `nid`: compute the node at the
    /// kernel's scheduled precision (cached in `preps`), apply the cached
    /// epilogue intrinsics the kernel recorded, then the layer's absorbed
    /// BN/activation chain — all into `st`'s arena-owned buffers through
    /// the shared kernel cores, no allocation on the success path.
    fn fire_into(
        &self,
        nid: NodeId,
        intrinsic: &[Epilogue],
        st: &mut FrameState,
    ) -> Result<(), String> {
        let g = self.graph;
        let n = &g.nodes[nid];
        // Ensure inputs exist (materializing layout nodes) *before*
        // detaching the output buffer.
        for &i in &n.inputs {
            self.ensure_value(i, st)?;
        }
        let mut out = std::mem::take(&mut st.values[nid]);
        match &n.op {
            Op::Conv2d { kernel, stride, padding, .. }
            | Op::DepthwiseConv2d { kernel, stride, padding, .. } => {
                let depthwise = matches!(n.op, Op::DepthwiseConv2d { .. });
                let x = &st.values[n.inputs[0]];
                let geom = ConvGeom::from_shapes(
                    &g.nodes[n.inputs[0]].shape,
                    &n.shape,
                    *kernel,
                    *stride,
                    *padding,
                    depthwise,
                );
                let bias = self.oracle.bias(nid);
                let f16 = matches!(self.preps[nid], InterpPrep::F16 { .. });
                let ep = |v: f32, o: usize| apply_conv_epilogue(v, o, bias, intrinsic, f16);
                match &self.preps[nid] {
                    InterpPrep::Int8(ip) => {
                        let qxs = &mut st.qx[..x.len()];
                        quantize_into(x, &ip.xq, qxs);
                        let dp = MatOperands::Int8 { qx: qxs, qw: &ip.qw, sx: ip.sx, wq: &ip.wq };
                        conv_core_into(&dp, geom, ep, &mut out);
                    }
                    InterpPrep::F16 { rw } => {
                        let rxs = &mut st.rx[..x.len()];
                        f16_round_into(x, rxs);
                        conv_core_into(&MatOperands::F16 { rx: rxs, rw }, geom, ep, &mut out);
                    }
                    _ => {
                        let dp = MatOperands::F32 { x, w: self.oracle.weights(nid) };
                        conv_core_into(&dp, geom, ep, &mut out);
                    }
                }
            }
            Op::Dense { .. } => {
                let x = &st.values[n.inputs[0]];
                let bias = self.oracle.bias(nid);
                let cin = x.len();
                let oc = bias.len().max(self.oracle.weights(nid).len() / cin.max(1));
                // The oracle's dense fp16 path rounds *before* the bias
                // (conv rounds after; rounding sits inside the dense
                // core) — mirrored, and documented in docs/VERIFICATION.md.
                let ep = |mut v: f32, o: usize| {
                    for e in intrinsic {
                        match e {
                            Epilogue::BiasAdd => v += bias[o],
                            Epilogue::Activation(a) => v = activate(v, *a),
                            Epilogue::BatchNormFold => {}
                        }
                    }
                    v
                };
                match &self.preps[nid] {
                    InterpPrep::Int8(ip) => {
                        let qxs = &mut st.qx[..cin];
                        quantize_into(x, &ip.xq, qxs);
                        let dp = MatOperands::Int8 { qx: qxs, qw: &ip.qw, sx: ip.sx, wq: &ip.wq };
                        dense_core_into(&dp, cin, oc, ep, &mut out);
                    }
                    InterpPrep::F16 { rw } => {
                        let rxs = &mut st.rx[..cin];
                        f16_round_into(x, rxs);
                        dense_core_into(&MatOperands::F16 { rx: rxs, rw }, cin, oc, ep, &mut out);
                    }
                    _ => {
                        let dp = MatOperands::F32 { x, w: self.oracle.weights(nid) };
                        dense_core_into(&dp, cin, oc, ep, &mut out);
                    }
                }
            }
            Op::BatchNorm => {
                self.batchnorm_node(nid, &st.values[n.inputs[0]], &mut out);
            }
            Op::Activate(a) => {
                for (o, &v) in out.iter_mut().zip(&st.values[n.inputs[0]]) {
                    *o = activate(v, *a);
                }
            }
            Op::MaxPool { kernel, stride, padding } => pool_into(
                &st.values[n.inputs[0]],
                &g.nodes[n.inputs[0]].shape,
                &n.shape,
                *kernel,
                *stride,
                *padding,
                true,
                &mut out,
            ),
            Op::AvgPool { kernel, stride, padding } => pool_into(
                &st.values[n.inputs[0]],
                &g.nodes[n.inputs[0]].shape,
                &n.shape,
                *kernel,
                *stride,
                *padding,
                false,
                &mut out,
            ),
            Op::GlobalAvgPool => {
                let (c, h, w) = g.nodes[n.inputs[0]].shape.chw().expect("gap input CHW");
                let x = &st.values[n.inputs[0]];
                for (ch, o) in out.iter_mut().enumerate().take(c) {
                    *o = x[ch * h * w..(ch + 1) * h * w].iter().sum::<f32>() / (h * w) as f32;
                }
            }
            Op::Add => {
                let (a, b) = (&st.values[n.inputs[0]], &st.values[n.inputs[1]]);
                for ((o, &va), &vb) in out.iter_mut().zip(a).zip(b) {
                    *o = va + vb;
                }
            }
            Op::Softmax => {
                let x = &st.values[n.inputs[0]];
                let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                for (o, &v) in out.iter_mut().zip(x) {
                    *o = (v - m).exp();
                }
                let s: f32 = out.iter().sum();
                for o in out.iter_mut() {
                    *o /= s;
                }
            }
            Op::Quantize { precision } => {
                let x = &st.values[n.inputs[0]];
                match (&self.preps[nid], precision) {
                    (InterpPrep::Grid(qp), _) => {
                        for (o, &v) in out.iter_mut().zip(x) {
                            *o = qp.roundtrip(v as f64, 0) as f32;
                        }
                    }
                    (_, Precision::F16) => f16_round_into(x, &mut out),
                    _ => out.copy_from_slice(x),
                }
            }
            Op::Dequantize { .. } => out.copy_from_slice(&st.values[n.inputs[0]]),
            Op::Input | Op::Flatten | Op::Transform => {
                st.values[nid] = out;
                return Err(format!("layout node {} owns a kernel", n.name));
            }
        }
        st.values[nid] = out;
        st.produced[nid] = true;
        // Absorbed chain: runtime-parameterized epilogue per dispatch.
        if let Some(chain) = self.chains.get(&nid) {
            for &a in chain {
                let src = self.graph.nodes[a].inputs[0];
                if !st.produced[src] {
                    return Err(format!("absorbed node {a} has no input value"));
                }
                let mut buf = std::mem::take(&mut st.values[a]);
                match self.graph.nodes[a].op {
                    Op::BatchNorm => self.batchnorm_node(a, &st.values[src], &mut buf),
                    Op::Activate(act) => {
                        for (o, &v) in buf.iter_mut().zip(&st.values[src]) {
                            *o = activate(v, act);
                        }
                    }
                    _ => buf.copy_from_slice(&st.values[src]),
                }
                st.values[a] = buf;
                st.produced[a] = true;
            }
        }
        Ok(())
    }

    /// BatchNorm through the oracle's parameters (shared index
    /// arithmetic with [`crate::quant::exec::batchnorm_into`]).
    fn batchnorm_node(&self, nid: NodeId, x: &[f32], out: &mut [f32]) {
        batchnorm_into(
            x,
            self.oracle.weights(nid),
            self.oracle.bias(nid),
            channels_of(&self.graph.nodes[nid].shape),
            out,
        );
    }
}

/// Conv-family epilogue at one output element, honoring the kernel's
/// recorded intrinsics. fp16 datapaths round once after the bias and
/// before the first activation (the oracle's evaluation order).
fn apply_conv_epilogue(
    mut v: f32,
    o: usize,
    bias: &[f32],
    intrinsic: &[Epilogue],
    f16: bool,
) -> f32 {
    let mut rounded = !f16;
    for e in intrinsic {
        match e {
            Epilogue::BiasAdd => v += bias[o],
            Epilogue::Activation(a) => {
                if !rounded {
                    v = f16_round(v);
                    rounded = true;
                }
                v = activate(v, *a);
            }
            Epilogue::BatchNormFold => {}
        }
    }
    if !rounded {
        v = f16_round(v);
    }
    v
}

/// Intrinsic epilogue a node's op attributes imply (what `texpr::lower`
/// seeds the nest with).
pub fn expected_intrinsic(op: &Op) -> Vec<Epilogue> {
    let mut e = Vec::new();
    match op {
        Op::Conv2d { bias, activation, .. }
        | Op::DepthwiseConv2d { bias, activation, .. }
        | Op::Dense { bias, activation, .. } => {
            if *bias {
                e.push(Epilogue::BiasAdd);
            }
            if *activation != Activation::None {
                e.push(Epilogue::Activation(*activation));
            }
        }
        _ => {}
    }
    e
}

/// The BN/activation nodes absorbed into `host`'s kernel, in absorption
/// order: follow single-consumer edges to epilogue ops that own no kernel.
pub fn absorbed_chain(
    graph: &Graph,
    map: &BTreeMap<NodeId, usize>,
    consumers: &[Vec<NodeId>],
    host: NodeId,
) -> Vec<NodeId> {
    let mut chain = Vec::new();
    let mut cur = host;
    loop {
        if consumers[cur].len() != 1 {
            break;
        }
        let next = consumers[cur][0];
        let absorbable = !map.contains_key(&next)
            && matches!(graph.nodes[next].op, Op::BatchNorm | Op::Activate(_));
        if !absorbable {
            break;
        }
        chain.push(next);
        cur = next;
    }
    chain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::patterns::{build_with_passes, default_factors, OptConfig};
    use crate::flow::Mode;
    use crate::graph::models;
    use crate::quant::calibrate::{calibrate_analytic, Calibrator};

    fn interp_setup(
        mode: Mode,
        cfg: &OptConfig,
    ) -> (Graph, crate::codegen::KernelProgram) {
        let g = models::lenet5();
        let plan = default_factors(&g);
        let built = build_with_passes(&g, mode, cfg, &plan);
        (g, built.program)
    }

    #[test]
    fn well_formed_programs_have_no_violations() {
        for mode in [Mode::Pipelined, Mode::Folded] {
            for cfg in [OptConfig::base(), OptConfig::optimized()] {
                let (g, prog) = interp_setup(mode, &cfg);
                let exec = Executor::new(&g);
                let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
                let itp = Interpreter::new(
                    &g,
                    &prog,
                    &exec,
                    &table,
                    QScheme::PerChannel,
                    Precision::F32,
                );
                assert_eq!(itp.structure(), &[] as &[String], "{mode:?} {cfg:?}");
            }
        }
    }

    #[test]
    fn interpreter_matches_oracle_on_lenet_f32() {
        let (g, prog) = interp_setup(Mode::Pipelined, &OptConfig::optimized());
        let exec = Executor::new(&g);
        let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
        let itp =
            Interpreter::new(&g, &prog, &exec, &table, QScheme::PerChannel, Precision::F32);
        let data = crate::data::mnist_like(2, 32, 7);
        for i in 0..2 {
            let want = exec.forward(data.frame(i), |_, _| {});
            let got = itp.run_frame(data.frame(i)).unwrap().logits;
            assert_eq!(want.len(), got.len());
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a, b, "f32 interpretation should mirror the oracle bitwise");
            }
        }
    }

    #[test]
    fn structural_checks_flag_broken_programs() {
        let (g, mut prog) = interp_setup(Mode::Pipelined, &OptConfig::optimized());
        // Drop the first kernel's epilogue: the chain check must fire.
        let victim = prog
            .kernels
            .iter_mut()
            .find(|k| !k.nest.epilogue.is_empty())
            .expect("lenet has epilogues");
        victim.nest.epilogue.clear();
        let exec = Executor::new(&g);
        let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
        let itp =
            Interpreter::new(&g, &prog, &exec, &table, QScheme::PerChannel, Precision::F32);
        assert!(
            itp.structure().iter().any(|v| v.contains("epilogue")),
            "{:?}",
            itp.structure()
        );
    }

    #[test]
    fn channel_mis_wiring_is_flagged() {
        let (g, mut prog) = interp_setup(Mode::Pipelined, &OptConfig::optimized());
        assert!(!prog.channels.is_empty());
        // Re-point one channel at its own producer: now one graph edge has
        // no channel and one channel matches no edge.
        let last = prog.kernels.len() - 1;
        prog.channels[0].to_kernel = if prog.channels[0].to_kernel == last { 0 } else { last };
        let exec = Executor::new(&g);
        let table = calibrate_analytic(&g, Calibrator::Percentile(99.9));
        let itp =
            Interpreter::new(&g, &prog, &exec, &table, QScheme::PerChannel, Precision::F32);
        assert!(
            itp.structure().iter().any(|v| v.contains("channel")),
            "{:?}",
            itp.structure()
        );
    }
}
