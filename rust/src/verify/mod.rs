//! Differential verification: prove the pass pipeline is
//! semantics-preserving.
//!
//! Every Table-I optimization rewrites [`KernelProgram`]s, and the
//! paper's quality claims rest on those programs still computing the
//! frozen network. This subsystem closes that loop:
//!
//! * [`interp`] — a functional interpreter that executes the *lowered*
//!   program (channel dataflow, per-dispatch parameterized layers, fused
//!   epilogues, f32/fp16/int8 datapaths) plus structural validation of
//!   autorun/channel/stash invariants;
//! * the graph-level [`crate::quant::Executor`] is the **oracle** — both
//!   sides share its deterministic synthetic weights and one calibration
//!   table, so int8 programs must agree **bit-exactly** with
//!   [`Executor::forward_quantized`] and float programs within the
//!   documented tolerance ([`rel_tolerance`]);
//! * [`differ`] — a fuzzing harness over randomized (network × pass
//!   subset × precision × mode) scenarios with a shrinker that reduces
//!   any counterexample to a minimal (net, config, frame) reproducer.
//!
//! Entry points: [`verify_program`] (one program against the oracle),
//! [`crate::flow::CompileSession::verify`] (a staged-API verification
//! stage), `fpga-flow verify` (CLI sweep over the canonical pipeline's
//! pass subsets) and `rust/tests/differential.rs` (CI fuzzing).
//! Methodology, tolerances and known modeling gaps are documented in
//! `docs/VERIFICATION.md`.
//!
//! [`Executor::forward_quantized`]: crate::quant::Executor::forward_quantized

pub mod differ;
pub mod interp;

pub use differ::{shrink, Fault, NetSpec, Reproducer, Scenario};
pub use interp::Interpreter;

use crate::codegen::KernelProgram;
use crate::graph::{Graph, NodeId};
use crate::pass::Equivalence;
use crate::quant::calibrate::{calibrate_analytic, Calibrator};
use crate::quant::exec::{Executor, FastExecutor};
use crate::quant::scheme::QScheme;
use crate::texpr::Precision;
use crate::util::rng::Rng;
use crate::util::scratch::Scratch;

/// How the verifier calibrates and quantizes (shared by both sides).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VerifyOptions {
    pub scheme: QScheme,
    pub calibrator: Calibrator,
}

impl Default for VerifyOptions {
    fn default() -> Self {
        VerifyOptions { scheme: QScheme::PerChannel, calibrator: Calibrator::Percentile(99.9) }
    }
}

/// Documented agreement bound, as a fraction of the logit scale, keyed
/// by datapath precision *and* the trace's declared obligation
/// ([`Equivalence`]): int8 always demands bit-exactness (integer
/// accumulation has no rounding freedom); f32 is bit-exact too **unless**
/// a float-tolerant pass (OF `-fp-relaxed`, BN-fold) actually applied, in
/// which case reassociation headroom of 1e-5 is granted; fp16
/// additionally tolerates its 11-bit significand.
pub fn rel_tolerance(precision: Precision, equivalence: Equivalence) -> f64 {
    match precision {
        Precision::Int8 => 0.0,
        Precision::F32 => {
            if equivalence == Equivalence::FloatTolerant {
                1e-5
            } else {
                0.0
            }
        }
        Precision::F16 => 1e-3,
    }
}

/// First node where the program diverged from the oracle.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeMismatch {
    pub node: NodeId,
    pub name: String,
    /// Frame index (into the verified frame set) that diverged.
    pub frame: usize,
    /// Relative error at that node, against the node's own value scale.
    pub rel_err: f64,
}

/// Outcome of verifying one program against the oracle.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    /// `KernelProgram::name` (carries network + mode).
    pub program: String,
    pub precision: Precision,
    /// What the applied passes promised ([`Equivalence`]) — together with
    /// the precision this keys the pass/fail tolerance
    /// ([`rel_tolerance`]): an f32 program whose trace never applied a
    /// float-tolerant pass must match the oracle bit-for-bit.
    pub equivalence: Equivalence,
    pub frames: usize,
    /// Applied relative tolerance ([`rel_tolerance`]).
    pub tolerance: f64,
    /// Worst relative logit error observed across all frames.
    pub max_rel_err: f64,
    /// Every logit of every frame was bitwise equal to the oracle's.
    pub bit_exact: bool,
    /// Structural invariant violations (autorun/channel/stash/epilogue).
    pub violations: Vec<String>,
    /// Dataflow execution failure, if the program could not run at all.
    pub failure: Option<String>,
    /// First diverging node (localization), when agreement failed.
    pub first_mismatch: Option<NodeMismatch>,
    pub passed: bool,
}

impl VerifyReport {
    /// One-line human summary (CLI tables, panic messages).
    pub fn summary(&self) -> String {
        let verdict = if self.passed { "PASS" } else { "FAIL" };
        let mut s = format!(
            "{verdict} {} [{}] {} frame(s): max rel err {:.3e} (tol {:.1e}{})",
            self.program,
            self.precision,
            self.frames,
            self.max_rel_err,
            self.tolerance,
            if self.tolerance == 0.0 { ", bit-exact required" } else { "" },
        );
        if let Some(m) = &self.first_mismatch {
            s.push_str(&format!("; first divergence at {} (frame {})", m.name, m.frame));
        }
        if let Some(f) = &self.failure {
            s.push_str(&format!("; execution failed: {f}"));
        }
        if !self.violations.is_empty() {
            s.push_str(&format!("; {} structural violation(s): {}", self.violations.len(), self.violations.join(" | ")));
        }
        s
    }
}

/// Deterministic verification frames for a graph: the network's
/// representative dataset when one exists, else seeded synthetic frames
/// shaped like bounded image strokes.
pub fn frames_for(graph: &Graph, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let n = n.max(1);
    let elems = graph.nodes[graph.input].shape.elems();
    if let Some(batch) = crate::data::for_network(&graph.name, n, seed) {
        if batch.frame_elems() == elems {
            return (0..n.min(batch.frames())).map(|i| batch.frame(i).to_vec()).collect();
        }
    }
    let mut rng = Rng::new(seed ^ crate::util::fnv64(graph.name.as_bytes()));
    (0..n)
        .map(|_| (0..elems).map(|_| 0.1 + 0.45 * rng.normal().abs()).collect())
        .collect()
}

/// Run `frames` through both the kernel-program interpreter and the graph
/// oracle and report agreement. Both sides share the oracle's synthetic
/// weights and one analytic calibration table, so any disagreement is a
/// property of the *program*, not of data or parameters.
pub fn verify_program(
    graph: &Graph,
    program: &KernelProgram,
    precision: Precision,
    equivalence: Equivalence,
    frames: &[Vec<f32>],
    opts: &VerifyOptions,
) -> VerifyReport {
    verify_program_in(graph, program, precision, equivalence, frames, opts, &mut Scratch::new())
}

/// [`verify_program`] over a caller-owned [`Scratch`] arena — the fuzzing
/// harness's steady-state entry point. Both sides run arena-backed: the
/// oracle through a fused [`FastExecutor`] (bit-identical to the
/// allocating baseline at every precision — see
/// `rust/tests/fastpath_equivalence.rs`), the program through the
/// interpreter's [`interp::FrameState`]. Mismatch localization is the
/// cold path and keeps the allocating observed re-runs.
pub fn verify_program_in(
    graph: &Graph,
    program: &KernelProgram,
    precision: Precision,
    equivalence: Equivalence,
    frames: &[Vec<f32>],
    opts: &VerifyOptions,
    scratch: &mut Scratch,
) -> VerifyReport {
    let exec = Executor::new(graph);
    let table = calibrate_analytic(graph, opts.calibrator);
    let interp = Interpreter::new(graph, program, &exec, &table, opts.scheme, precision);
    let violations = interp.structure().to_vec();
    let tolerance = rel_tolerance(precision, equivalence);

    let mut oracle = if precision == Precision::F32 {
        FastExecutor::reference(&exec, true, scratch)
    } else {
        FastExecutor::quantized(&exec, &table, precision, opts.scheme, true, scratch)
    };
    let mut st = interp.frame_state(scratch);

    let mut max_rel_err = 0f64;
    let mut bit_exact = true;
    let mut failure = None;
    let mut first_mismatch: Option<NodeMismatch> = None;

    for (fi, frame) in frames.iter().enumerate() {
        // Observer-free oracle pass first — per-node activations are only
        // materialized below when this frame actually diverges (both
        // sides are deterministic, so the re-run reproduces the state).
        let oracle_logits = oracle.forward(frame);
        if let Err(e) = interp.run_frame_into(frame, &mut st) {
            failure = Some(e);
            break;
        }
        let rel = slice_rel_err(oracle_logits, interp.logits(&st));
        if rel > 0.0 {
            bit_exact = false;
        }
        if rel > max_rel_err {
            max_rel_err = rel;
        }
        if rel > tolerance && first_mismatch.is_none() {
            let run = match interp.run_frame(frame) {
                Ok(run) => run,
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            };
            // Localize: re-run the oracle observing every node, and find
            // the first topological node whose program value diverges
            // beyond the tolerance.
            let mut oracle_nodes: Vec<Vec<f32>> = vec![Vec::new(); graph.nodes.len()];
            if precision == Precision::F32 {
                exec.forward(frame, |id, a| oracle_nodes[id] = a.to_vec());
            } else {
                exec.forward_quantized_observed(frame, &table, precision, opts.scheme, |id, a| {
                    oracle_nodes[id] = a.to_vec()
                });
            }
            for n in graph.topo() {
                let Some(got) = &run.per_node[n.id] else { continue };
                let want = &oracle_nodes[n.id];
                if want.is_empty() {
                    continue;
                }
                let nrel = slice_rel_err(want, got);
                if nrel > tolerance {
                    first_mismatch = Some(NodeMismatch {
                        node: n.id,
                        name: n.name.clone(),
                        frame: fi,
                        rel_err: nrel,
                    });
                    break;
                }
            }
            if first_mismatch.is_none() {
                // Logits disagreed but no single node exceeded tolerance
                // (accumulated drift): point at the output.
                first_mismatch = Some(NodeMismatch {
                    node: graph.output,
                    name: graph.nodes[graph.output].name.clone(),
                    frame: fi,
                    rel_err: rel,
                });
            }
        }
    }
    oracle.release(scratch);
    interp.release_state(st, scratch);

    let agreement_ok = if precision == Precision::Int8 {
        bit_exact
    } else {
        max_rel_err <= tolerance
    };
    let passed = violations.is_empty() && failure.is_none() && agreement_ok;
    VerifyReport {
        program: program.name.clone(),
        precision,
        equivalence,
        frames: frames.len(),
        tolerance,
        max_rel_err,
        bit_exact,
        violations,
        failure,
        first_mismatch,
        passed,
    }
}

/// Verify a pipeline partition against the unpartitioned oracle: split
/// `graph` at `cuts`, execute the stage chain (each stage's output tensor
/// is the next stage's input frame, exactly what the host channels carry),
/// and diff the final logits against the whole-graph reference at
/// `precision`.
///
/// Both sides share the oracle's parameters and calibration by
/// construction: stage executors draw each node's synthetic weights from
/// its *parent* node's seed stream ([`Executor::for_stage`]) and
/// re-quantize boundary activations with the whole-network calibrated
/// range ([`CalibrationTable::for_stage`]) — so a partition is a purely
/// structural rewrite and the report demands the [`Equivalence::BitExact`]
/// obligation (int8 bit-exact, f32 bit-exact, fp16 within its significand
/// tolerance).
///
/// [`CalibrationTable::for_stage`]: crate::quant::calibrate::CalibrationTable::for_stage
pub fn verify_partition(
    graph: &Graph,
    cuts: &[usize],
    precision: Precision,
    frames: &[Vec<f32>],
    opts: &VerifyOptions,
) -> VerifyReport {
    let k = cuts.len() + 1;
    let program = format!("{}_pipeline_k{k}", graph.name);
    let equivalence = Equivalence::BitExact;
    let tolerance = rel_tolerance(precision, equivalence);
    let fail = |msg: String| VerifyReport {
        program: program.clone(),
        precision,
        equivalence,
        frames: frames.len(),
        tolerance,
        max_rel_err: f64::INFINITY,
        bit_exact: false,
        violations: Vec::new(),
        failure: Some(msg),
        first_mismatch: None,
        passed: false,
    };
    let Some(stages) = crate::pass::partition::split_stages(graph, cuts) else {
        return fail(format!("cuts {cuts:?} are not clean single-value frontiers"));
    };

    let exec = Executor::new(graph);
    let table = calibrate_analytic(graph, opts.calibrator);
    let stage_execs: Vec<Executor> = stages
        .iter()
        .map(|s| Executor::for_stage(&s.graph, &graph.name, &s.parent_ids))
        .collect();
    let stage_tables: Vec<_> =
        stages.iter().map(|s| table.for_stage(&s.graph.name, &s.parent_ids)).collect();

    let run_chain = |frame: &[f32], mut observe: &mut dyn FnMut(usize, NodeId, &[f32])| {
        let mut tensor = frame.to_vec();
        for (si, se) in stage_execs.iter().enumerate() {
            let obs = &mut observe;
            tensor = if precision == Precision::F32 {
                se.forward(&tensor, |id, a| obs(si, id, a))
            } else {
                se.forward_quantized_observed(
                    &tensor,
                    &stage_tables[si],
                    precision,
                    opts.scheme,
                    |id, a| obs(si, id, a),
                )
            };
        }
        tensor
    };

    let mut max_rel_err = 0f64;
    let mut bit_exact = true;
    let mut first_mismatch: Option<NodeMismatch> = None;
    for (fi, frame) in frames.iter().enumerate() {
        let want = if precision == Precision::F32 {
            exec.forward(frame, |_, _| {})
        } else {
            exec.forward_quantized(frame, &table, precision, opts.scheme)
        };
        let got = run_chain(frame, &mut |_, _, _| {});
        let rel = slice_rel_err(&want, &got);
        if rel > 0.0 {
            bit_exact = false;
        }
        if rel > max_rel_err {
            max_rel_err = rel;
        }
        if rel > tolerance && first_mismatch.is_none() {
            // Localize to the first parent node whose chained value
            // diverges — stage Input re-materializations are skipped (they
            // duplicate the boundary producer's parent id).
            let mut oracle_nodes: Vec<Vec<f32>> = vec![Vec::new(); graph.nodes.len()];
            if precision == Precision::F32 {
                exec.forward(frame, |id, a| oracle_nodes[id] = a.to_vec());
            } else {
                exec.forward_quantized_observed(frame, &table, precision, opts.scheme, |id, a| {
                    oracle_nodes[id] = a.to_vec()
                });
            }
            let mut worst: Option<NodeMismatch> = None;
            run_chain(frame, &mut |si, id, a| {
                if worst.is_some() {
                    return;
                }
                let pid = stages[si].parent_ids[id];
                if si > 0 && id == 0 {
                    return;
                }
                let want = &oracle_nodes[pid];
                if want.is_empty() {
                    return;
                }
                let nrel = slice_rel_err(want, a);
                if nrel > tolerance {
                    worst = Some(NodeMismatch {
                        node: pid,
                        name: graph.nodes[pid].name.clone(),
                        frame: fi,
                        rel_err: nrel,
                    });
                }
            });
            first_mismatch = worst.or_else(|| {
                Some(NodeMismatch {
                    node: graph.output,
                    name: graph.nodes[graph.output].name.clone(),
                    frame: fi,
                    rel_err: rel,
                })
            });
        }
    }

    let agreement_ok =
        if precision == Precision::Int8 { bit_exact } else { max_rel_err <= tolerance };
    VerifyReport {
        program,
        precision,
        equivalence,
        frames: frames.len(),
        tolerance,
        max_rel_err,
        bit_exact,
        violations: Vec::new(),
        failure: None,
        first_mismatch,
        passed: agreement_ok,
    }
}

/// Worst per-element error of `got` against `want`, relative to `want`'s
/// own magnitude scale (length mismatch or a NaN on either side =
/// infinite error). Exactly equal elements contribute 0 regardless of
/// scale.
fn slice_rel_err(want: &[f32], got: &[f32]) -> f64 {
    if want.len() != got.len() {
        return f64::INFINITY;
    }
    let scale = want.iter().map(|v| v.abs()).fold(0f32, f32::max).max(1e-3) as f64;
    let mut worst = 0f64;
    for (&a, &b) in want.iter().zip(got) {
        if a == b {
            continue;
        }
        // A NaN on either side is an unconditional failure: NaN compares
        // false against every threshold, so propagating it raw would let
        // a NaN-emitting program bug slip through as "0 error".
        let diff = (a as f64 - b as f64).abs();
        let rel = if diff.is_nan() { f64::INFINITY } else { diff / scale };
        if rel > worst {
            worst = rel;
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::patterns::{build_with_passes, default_factors, OptConfig};
    use crate::flow::Mode;
    use crate::graph::models;

    fn verify_lenet(mode: Mode, precision: Precision, cfg: OptConfig) -> VerifyReport {
        let g = models::lenet5();
        let plan = default_factors(&g);
        let cfg = cfg.with_precision(precision);
        let built = build_with_passes(&g, mode, &cfg, &plan);
        let frames = frames_for(&g, 3, 11);
        verify_program(
            &g,
            &built.program,
            precision,
            built.trace.required_equivalence(),
            &frames,
            &VerifyOptions::default(),
        )
    }

    #[test]
    fn lenet_verifies_across_modes_and_precisions() {
        for mode in [Mode::Pipelined, Mode::Folded] {
            for p in Precision::all() {
                for cfg in [OptConfig::base(), OptConfig::optimized()] {
                    let rep = verify_lenet(mode, p, cfg);
                    assert!(rep.passed, "{mode:?} {p} {cfg:?}: {}", rep.summary());
                    if p == Precision::Int8 {
                        assert!(rep.bit_exact, "{}", rep.summary());
                    }
                }
            }
        }
    }

    #[test]
    fn frames_are_deterministic_and_shaped() {
        let g = models::lenet5();
        let a = frames_for(&g, 4, 9);
        let b = frames_for(&g, 4, 9);
        assert_eq!(a.len(), 4);
        assert_eq!(a, b);
        assert!(a.iter().all(|f| f.len() == g.nodes[g.input].shape.elems()));
        // Unknown graphs synthesize deterministic frames too.
        let (mut gb, x) = crate::graph::GraphBuilder::new("no-such-net", crate::graph::Shape::Chw(2, 8, 8));
        let f = gb.add("f", crate::graph::Op::Flatten, &[x]);
        let g2 = gb.finish(f);
        let c = frames_for(&g2, 2, 1);
        let d = frames_for(&g2, 2, 1);
        assert_eq!(c, d);
        assert_eq!(c[0].len(), 2 * 8 * 8);
        assert_ne!(c[0], c[1]);
    }

    #[test]
    fn tolerances_enforce_the_declared_obligation() {
        use crate::pass::Equivalence as E;
        // int8 is bit-exact no matter what the passes claim.
        assert_eq!(rel_tolerance(Precision::Int8, E::FloatTolerant), 0.0);
        // f32 is bit-exact unless a float-tolerant pass actually applied —
        // cost-model-only passes (VT/SP) grant no drift headroom.
        assert_eq!(rel_tolerance(Precision::F32, E::BitExact), 0.0);
        assert_eq!(rel_tolerance(Precision::F32, E::CostModelOnly), 0.0);
        assert_eq!(rel_tolerance(Precision::F32, E::GridExact), 0.0);
        assert!(rel_tolerance(Precision::F32, E::FloatTolerant) > 0.0);
        assert!(
            rel_tolerance(Precision::F32, E::FloatTolerant)
                < rel_tolerance(Precision::F16, E::FloatTolerant)
        );
        // FloatTolerant dominates the max-fold even when cost-only passes
        // rode along.
        assert_eq!(E::CostModelOnly.max(E::FloatTolerant), E::FloatTolerant);
    }

    #[test]
    fn partition_chain_matches_whole_graph_at_every_precision() {
        let g = models::lenet5();
        let cuts = crate::pass::partition::candidate_cuts(&g);
        assert!(!cuts.is_empty());
        let frames = frames_for(&g, 3, 17);
        for p in Precision::all() {
            let rep = verify_partition(&g, &cuts[..1], p, &frames, &VerifyOptions::default());
            assert!(rep.passed, "{p}: {}", rep.summary());
            if p != Precision::F16 {
                assert!(rep.bit_exact, "{p} chained stages must be bit-exact: {}", rep.summary());
            }
        }
        // Illegal cuts are reported as a failure, not a panic.
        let bad = verify_partition(&g, &[0], Precision::F32, &frames, &VerifyOptions::default());
        assert!(!bad.passed && bad.failure.is_some());
    }

    #[test]
    fn slice_rel_err_behaves() {
        assert_eq!(slice_rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!(slice_rel_err(&[1.0, 2.0], &[1.0]) == f64::INFINITY);
        let e = slice_rel_err(&[0.0, 10.0], &[1.0, 10.0]);
        assert!((e - 0.1).abs() < 1e-12, "{e}");
        // NaN anywhere is an unconditional (infinite) failure — it must
        // never slip through the `> tolerance` comparisons as 0 error.
        assert_eq!(slice_rel_err(&[1.0, f32::NAN], &[1.0, 2.0]), f64::INFINITY);
        assert_eq!(slice_rel_err(&[1.0, 2.0], &[1.0, f32::NAN]), f64::INFINITY);
        assert_eq!(slice_rel_err(&[f32::NAN], &[f32::NAN]), f64::INFINITY);
    }
}
